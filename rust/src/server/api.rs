//! Typed `/v1` API layer: request validation, structured errors, and
//! OpenAI-style completion / SSE chunk serialization. This replaces
//! hand-rolled JSON poking in the HTTP handlers — everything the wire
//! protocol says lives here, everything about sockets lives in `mod.rs`.

use crate::config::ServingConfig;
use crate::engine::{GenRequest, Priority, SubmitError, Usage};
use crate::model::tokenizer;
use crate::util::json::Json;

/// Bodies larger than this are rejected with 413 instead of truncated.
pub const MAX_BODY_BYTES: usize = 16 << 20;

/// Hard ceiling on `max_tokens` regardless of engine config.
pub const MAX_TOKENS_CAP: usize = 65_536;

// ---------------------------------------------------------------------
// Errors
// ---------------------------------------------------------------------

/// A structured API error: HTTP status + machine-readable type +
/// human-readable message, serialized as
/// `{"error":{"type":...,"message":...}}`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ApiError {
    pub status: u16,
    pub code: &'static str,
    pub message: String,
    /// Retryable rejections (429/503 from admission control or load
    /// shedding) carry a hint the server emits as a `Retry-After`
    /// header, rounded up to whole seconds.
    pub retry_after_ms: Option<u64>,
}

impl ApiError {
    fn new(status: u16, code: &'static str, message: String) -> Self {
        Self { status, code, message, retry_after_ms: None }
    }

    pub fn invalid_request(message: impl Into<String>) -> Self {
        Self::new(400, "invalid_request_error", message.into())
    }

    pub fn not_found(path: &str) -> Self {
        Self::new(404, "not_found_error", format!("no route for {path}"))
    }

    pub fn method_not_allowed(method: &str) -> Self {
        Self::new(405, "method_not_allowed", format!("method '{method}' not allowed"))
    }

    pub fn payload_too_large(len: usize) -> Self {
        Self::new(
            413,
            "payload_too_large",
            format!("body of {len} bytes exceeds the {MAX_BODY_BYTES} byte limit"),
        )
    }

    pub fn request_timeout(message: impl Into<String>) -> Self {
        Self::new(408, "request_timeout", message.into())
    }

    pub fn overloaded(message: impl Into<String>) -> Self {
        Self::new(429, "overloaded_error", message.into())
    }

    pub fn internal(message: impl Into<String>) -> Self {
        Self::new(500, "internal_error", message.into())
    }

    pub fn unavailable(message: impl Into<String>) -> Self {
        Self::new(503, "service_unavailable", message.into())
    }

    pub fn with_retry_after(mut self, ms: u64) -> Self {
        self.retry_after_ms = Some(ms);
        self
    }

    /// The `Retry-After` header value in whole seconds (rounded up,
    /// minimum 1), when this error carries a retry hint.
    pub fn retry_after_secs(&self) -> Option<u64> {
        self.retry_after_ms.map(|ms| ms.div_ceil(1000).max(1))
    }

    /// Map an engine-side session failure message to an HTTP status.
    /// Capacity failures (KV pressure that outlived the preemption
    /// budget) and load-shed displacements are retryable 503s;
    /// everything else is a 500.
    pub fn from_session_failure(message: &str) -> Self {
        if message.starts_with("capacity:") {
            Self::unavailable(message)
        } else if message.starts_with("shed:") {
            Self::unavailable(message).with_retry_after(1000)
        } else {
            Self::internal(message)
        }
    }

    pub fn body(&self) -> String {
        Json::obj()
            .with(
                "error",
                Json::obj()
                    .with("type", self.code)
                    .with("message", self.message.as_str()),
            )
            .to_string()
    }
}

impl From<SubmitError> for ApiError {
    fn from(e: SubmitError) -> Self {
        match e {
            SubmitError::QueueFull { .. } => Self::overloaded(e.to_string()).with_retry_after(1000),
            SubmitError::TooLong { .. } => Self::invalid_request(e.to_string()),
            SubmitError::RateLimited { retry_after_ms } => {
                Self::overloaded(e.to_string()).with_retry_after(retry_after_ms)
            }
            SubmitError::Shed { retry_after_ms } => {
                Self::unavailable(e.to_string()).with_retry_after(retry_after_ms)
            }
            SubmitError::Draining => Self::unavailable(e.to_string()).with_retry_after(1000),
        }
    }
}

// ---------------------------------------------------------------------
// Requests
// ---------------------------------------------------------------------

/// A validated `POST /v1/completions` body.
#[derive(Debug, Clone, PartialEq)]
pub struct CompletionRequest {
    pub prompt: String,
    pub max_tokens: usize,
    pub temperature: Option<f32>,
    pub greedy: Option<bool>,
    pub seed: Option<u64>,
    /// Stop at the first byte of this string (byte-level tokenizer).
    pub stop: Option<i32>,
    pub stream: bool,
    /// Shared-prefix KV reuse for this request (`"cache": "off"` or
    /// `false` opts out; default on, subject to the server-wide knob).
    pub cache: bool,
    /// Per-request wall-clock deadline in milliseconds. `None` defers
    /// to the server-wide `timeout_ms`; `Some(0)` opts out entirely.
    pub timeout_ms: Option<u64>,
    /// Admission priority class (`"high"` / `"normal"` / `"batch"`);
    /// lower classes are shed first under load.
    pub priority: Priority,
}

impl CompletionRequest {
    /// Parse + validate a JSON body. Unknown fields are ignored
    /// (OpenAI-compatible); wrong types and out-of-range values are
    /// structured 400s.
    pub fn from_json(j: &Json) -> Result<Self, ApiError> {
        if j.get("prompt").is_none() {
            return Err(ApiError::invalid_request("missing required field 'prompt'"));
        }
        let prompt = j
            .get("prompt")
            .and_then(Json::as_str)
            .ok_or_else(|| ApiError::invalid_request("'prompt' must be a string"))?
            .to_string();
        if prompt.trim().is_empty() {
            return Err(ApiError::invalid_request(
                "'prompt' must contain at least one non-whitespace character",
            ));
        }
        let max_tokens = match j.get("max_tokens") {
            None => 64,
            Some(v) => {
                let n = v.as_f64().ok_or_else(|| {
                    ApiError::invalid_request("'max_tokens' must be a number")
                })?;
                if n.fract() != 0.0 || n < 1.0 {
                    return Err(ApiError::invalid_request(
                        "'max_tokens' must be an integer >= 1",
                    ));
                }
                n as usize
            }
        };
        if max_tokens > MAX_TOKENS_CAP {
            return Err(ApiError::invalid_request(format!(
                "'max_tokens' {max_tokens} exceeds cap {MAX_TOKENS_CAP}"
            )));
        }
        let temperature = match j.get("temperature") {
            None => None,
            Some(v) => {
                let t = v.as_f64().ok_or_else(|| {
                    ApiError::invalid_request("'temperature' must be a number")
                })?;
                if !(t > 0.0 && t <= 100.0) {
                    return Err(ApiError::invalid_request(
                        "'temperature' must be in (0, 100]",
                    ));
                }
                Some(t as f32)
            }
        };
        let greedy = match j.get("greedy") {
            None => None,
            Some(v) => Some(v.as_bool().ok_or_else(|| {
                ApiError::invalid_request("'greedy' must be a boolean")
            })?),
        };
        let seed = match j.get("seed") {
            None => None,
            Some(v) => {
                let s = v.as_f64().ok_or_else(|| {
                    ApiError::invalid_request("'seed' must be a number")
                })?;
                if s.fract() != 0.0 || s < 0.0 {
                    return Err(ApiError::invalid_request(
                        "'seed' must be a non-negative integer",
                    ));
                }
                Some(s as u64)
            }
        };
        let stop = match j.get("stop") {
            None => None,
            Some(v) => {
                let s = v.as_str().ok_or_else(|| {
                    ApiError::invalid_request("'stop' must be a string")
                })?;
                let b = s.as_bytes().first().ok_or_else(|| {
                    ApiError::invalid_request("'stop' must be non-empty")
                })?;
                Some(*b as i32)
            }
        };
        let stream = match j.get("stream") {
            None => false,
            Some(v) => v.as_bool().ok_or_else(|| {
                ApiError::invalid_request("'stream' must be a boolean")
            })?,
        };
        let cache = match j.get("cache") {
            None => true,
            Some(v) => match (v.as_bool(), v.as_str()) {
                (Some(b), _) => b,
                (_, Some("on")) => true,
                (_, Some("off")) => false,
                _ => {
                    return Err(ApiError::invalid_request(
                        "'cache' must be a boolean or \"on\"/\"off\"",
                    ))
                }
            },
        };
        let timeout_ms = match j.get("timeout_ms") {
            None => None,
            Some(v) => {
                let t = v.as_f64().ok_or_else(|| {
                    ApiError::invalid_request("'timeout_ms' must be a number")
                })?;
                if t.fract() != 0.0 || t < 0.0 {
                    return Err(ApiError::invalid_request(
                        "'timeout_ms' must be a non-negative integer",
                    ));
                }
                Some(t as u64)
            }
        };
        let priority = match j.get("priority") {
            None => Priority::default(),
            Some(v) => {
                let s = v.as_str().ok_or_else(|| {
                    ApiError::invalid_request("'priority' must be a string")
                })?;
                Priority::parse(s).ok_or_else(|| {
                    ApiError::invalid_request(
                        "'priority' must be one of \"high\", \"normal\", \"batch\"",
                    )
                })?
            }
        };
        Ok(Self {
            prompt,
            max_tokens,
            temperature,
            greedy,
            seed,
            stop,
            stream,
            cache,
            timeout_ms,
            priority,
        })
    }

    /// Lower into an engine request, checking engine-level limits.
    pub fn to_gen_request(&self, cfg: &ServingConfig) -> Result<GenRequest, ApiError> {
        let prompt = tokenizer::encode(&self.prompt);
        let need = prompt.len() + self.max_tokens;
        if need > cfg.max_seq_len {
            return Err(ApiError::invalid_request(format!(
                "prompt ({}) + max_tokens ({}) = {need} exceeds max_seq_len {}",
                prompt.len(),
                self.max_tokens,
                cfg.max_seq_len
            )));
        }
        let mut req = GenRequest::new(prompt, self.max_tokens);
        req.temperature = self.temperature;
        req.greedy = self.greedy;
        req.seed = self.seed;
        req.stop_token = self.stop;
        req.prefix_cache = self.cache;
        req.timeout_ms = self.timeout_ms;
        req.priority = self.priority;
        Ok(req)
    }
}

// ---------------------------------------------------------------------
// Responses
// ---------------------------------------------------------------------

fn usage_json(u: &Usage) -> Json {
    Json::obj()
        .with("prompt_tokens", u.prompt_tokens)
        .with("completion_tokens", u.completion_tokens)
        .with("total_tokens", u.total_tokens())
        .with("cached_tokens", u.cached_tokens)
        .with("prefill_ms", u.prefill_ms)
        .with("decode_ms", u.decode_ms)
}

/// Non-streaming `text_completion` response body.
pub fn completion_json(
    id: &str,
    model: &str,
    created: u64,
    text: &str,
    finish: &str,
    usage: &Usage,
) -> Json {
    Json::obj()
        .with("id", id)
        .with("object", "text_completion")
        .with("created", created as i64)
        .with("model", model)
        .with(
            "choices",
            vec![Json::obj()
                .with("index", 0usize)
                .with("text", text)
                .with("finish_reason", finish)],
        )
        .with("usage", usage_json(usage))
}

/// One SSE chunk (`object: "text_completion.chunk"`). `finish` is
/// `None` for token chunks and `Some(reason)` on the terminal chunk,
/// which also carries usage when available.
pub fn chunk_json(
    id: &str,
    model: &str,
    created: u64,
    text: &str,
    finish: Option<&str>,
    usage: Option<&Usage>,
) -> Json {
    let mut choice = Json::obj().with("index", 0usize).with("text", text);
    choice = match finish {
        Some(f) => choice.with("finish_reason", f),
        None => choice.with("finish_reason", Json::Null),
    };
    let mut j = Json::obj()
        .with("id", id)
        .with("object", "text_completion.chunk")
        .with("created", created as i64)
        .with("model", model)
        .with("choices", vec![choice]);
    if let Some(u) = usage {
        j = j.with("usage", usage_json(u));
    }
    j
}

/// Frame a JSON payload as one SSE event.
pub fn sse_event(j: &Json) -> String {
    format!("data: {j}\n\n")
}

/// Frame a JSON payload as one SSE event carrying an event id (the
/// 0-based token stream index). Clients echo the last id they saw via
/// `Last-Event-ID` to resume a stream without gaps or duplicates.
pub fn sse_event_id(id: u64, j: &Json) -> String {
    format!("id: {id}\ndata: {j}\n\n")
}

/// Stream terminator, after the final chunk.
pub const SSE_DONE: &str = "data: [DONE]\n\n";

pub fn unix_now() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(body: &str) -> Result<CompletionRequest, ApiError> {
        CompletionRequest::from_json(&Json::parse(body).unwrap())
    }

    #[test]
    fn minimal_request_gets_defaults() {
        let r = parse(r#"{"prompt":"hello"}"#).unwrap();
        assert_eq!(r.prompt, "hello");
        assert_eq!(r.max_tokens, 64);
        assert!(!r.stream);
        assert!(r.cache, "prefix cache defaults on");
        assert_eq!(r.temperature, None);
        assert_eq!(r.seed, None);
        assert_eq!(r.timeout_ms, None, "deadline defers to the server default");
    }

    #[test]
    fn full_request_roundtrip() {
        let r = parse(
            r#"{"prompt":"a","max_tokens":8,"temperature":0.5,
                "greedy":false,"seed":42,"stop":" ","stream":true}"#,
        )
        .unwrap();
        assert_eq!(r.max_tokens, 8);
        assert_eq!(r.seed, Some(42));
        assert_eq!(r.stop, Some(b' ' as i32));
        assert!(r.stream);
        assert_eq!(r.greedy, Some(false));
        assert!((r.temperature.unwrap() - 0.5).abs() < 1e-6);
    }

    #[test]
    fn validation_rejects_bad_fields() {
        assert_eq!(parse(r#"{}"#).unwrap_err().status, 400);
        assert_eq!(parse(r#"{"prompt":""}"#).unwrap_err().status, 400);
        assert_eq!(parse(r#"{"prompt":7}"#).unwrap_err().status, 400);
        assert_eq!(parse(r#"{"prompt":"a","max_tokens":0}"#).unwrap_err().status, 400);
        assert_eq!(parse(r#"{"prompt":"a","max_tokens":1.5}"#).unwrap_err().status, 400);
        assert_eq!(parse(r#"{"prompt":"a","temperature":-1}"#).unwrap_err().status, 400);
        assert_eq!(parse(r#"{"prompt":"a","stream":"yes"}"#).unwrap_err().status, 400);
        assert_eq!(parse(r#"{"prompt":"a","seed":-3}"#).unwrap_err().status, 400);
        assert_eq!(parse(r#"{"prompt":"a","stop":""}"#).unwrap_err().status, 400);
    }

    #[test]
    fn unknown_fields_ignored() {
        assert!(parse(r#"{"prompt":"a","model":"whatever","n":1}"#).is_ok());
    }

    #[test]
    fn cache_field_accepts_bool_and_switch_strings() {
        assert!(!parse(r#"{"prompt":"a","cache":false}"#).unwrap().cache);
        assert!(parse(r#"{"prompt":"a","cache":true}"#).unwrap().cache);
        assert!(!parse(r#"{"prompt":"a","cache":"off"}"#).unwrap().cache);
        assert!(parse(r#"{"prompt":"a","cache":"on"}"#).unwrap().cache);
        assert_eq!(parse(r#"{"prompt":"a","cache":"maybe"}"#).unwrap_err().status, 400);
        assert_eq!(parse(r#"{"prompt":"a","cache":1}"#).unwrap_err().status, 400);

        let cfg = ServingConfig::default();
        let off = parse(r#"{"prompt":"a","cache":"off"}"#).unwrap();
        assert!(!off.to_gen_request(&cfg).unwrap().prefix_cache);
        let on = parse(r#"{"prompt":"a"}"#).unwrap();
        assert!(on.to_gen_request(&cfg).unwrap().prefix_cache);
    }

    #[test]
    fn gen_request_respects_max_seq_len() {
        let cfg = ServingConfig::default();
        let r = parse(r#"{"prompt":"ab","max_tokens":16}"#).unwrap();
        let g = r.to_gen_request(&cfg).unwrap();
        assert_eq!(g.prompt.len(), 2);
        assert_eq!(g.max_new_tokens, 16);
        let mut small = cfg.clone();
        small.max_seq_len = 10;
        assert_eq!(r.to_gen_request(&small).unwrap_err().status, 400);
    }

    #[test]
    fn error_body_shape() {
        let e = ApiError::overloaded("queue full");
        let j = Json::parse(&e.body()).unwrap();
        assert_eq!(j.path("error.type").unwrap().as_str(), Some("overloaded_error"));
        assert_eq!(j.path("error.message").unwrap().as_str(), Some("queue full"));
    }

    #[test]
    fn timeout_ms_parses_and_threads_through() {
        let cfg = ServingConfig::default();
        let r = parse(r#"{"prompt":"a","timeout_ms":250}"#).unwrap();
        assert_eq!(r.timeout_ms, Some(250));
        assert_eq!(r.to_gen_request(&cfg).unwrap().timeout_ms, Some(250));
        // 0 is a valid explicit opt-out of the server default.
        assert_eq!(parse(r#"{"prompt":"a","timeout_ms":0}"#).unwrap().timeout_ms, Some(0));
        assert_eq!(parse(r#"{"prompt":"a","timeout_ms":-1}"#).unwrap_err().status, 400);
        assert_eq!(parse(r#"{"prompt":"a","timeout_ms":1.5}"#).unwrap_err().status, 400);
        assert_eq!(parse(r#"{"prompt":"a","timeout_ms":"soon"}"#).unwrap_err().status, 400);
    }

    #[test]
    fn session_failure_maps_capacity_to_503() {
        let e = ApiError::from_session_failure("capacity: no kv blocks after 4 preemptions");
        assert_eq!(e.status, 503);
        let e = ApiError::from_session_failure("decode panicked: boom");
        assert_eq!(e.status, 500);
        let e = ApiError::request_timeout("deadline exceeded");
        assert_eq!(e.status, 408);
        assert_eq!(e.code, "request_timeout");
    }

    #[test]
    fn submit_error_maps_to_http_status() {
        let e: ApiError = SubmitError::QueueFull { depth: 4 }.into();
        assert_eq!(e.status, 429);
        assert_eq!(e.retry_after_secs(), Some(1));
        let e: ApiError = SubmitError::TooLong { need: 10, max: 5 }.into();
        assert_eq!(e.status, 400);
        assert_eq!(e.retry_after_secs(), None);
        let e: ApiError = SubmitError::RateLimited { retry_after_ms: 2500 }.into();
        assert_eq!(e.status, 429);
        assert_eq!(e.retry_after_secs(), Some(3), "2500 ms rounds up to 3 s");
        let e: ApiError = SubmitError::Shed { retry_after_ms: 1 }.into();
        assert_eq!(e.status, 503);
        assert_eq!(e.retry_after_secs(), Some(1), "retry hint is at least one second");
        let e: ApiError = SubmitError::Draining.into();
        assert_eq!(e.status, 503);
        assert!(e.retry_after_secs().is_some());
    }

    #[test]
    fn whitespace_only_prompt_is_rejected() {
        for body in [r#"{"prompt":"   "}"#, "{\"prompt\":\"\\t\\n\"}"] {
            let e = parse(body).unwrap_err();
            assert_eq!(e.status, 400, "whitespace-only prompt must 400: {body}");
            assert!(e.message.contains("non-whitespace"), "got: {}", e.message);
        }
        assert!(parse(r#"{"prompt":" a "}"#).is_ok(), "interior whitespace is fine");
    }

    #[test]
    fn priority_parses_and_threads_through() {
        let cfg = ServingConfig::default();
        let r = parse(r#"{"prompt":"a"}"#).unwrap();
        assert_eq!(r.priority, Priority::Normal, "priority defaults to normal");
        for (s, want) in
            [("high", Priority::High), ("normal", Priority::Normal), ("batch", Priority::Batch)]
        {
            let r = parse(&format!(r#"{{"prompt":"a","priority":"{s}"}}"#)).unwrap();
            assert_eq!(r.priority, want);
            assert_eq!(r.to_gen_request(&cfg).unwrap().priority, want);
        }
        assert_eq!(parse(r#"{"prompt":"a","priority":"urgent"}"#).unwrap_err().status, 400);
        assert_eq!(parse(r#"{"prompt":"a","priority":7}"#).unwrap_err().status, 400);
    }

    #[test]
    fn shed_session_failure_maps_to_503_with_retry() {
        let e = ApiError::from_session_failure("shed: displaced by a higher-priority arrival");
        assert_eq!(e.status, 503);
        assert_eq!(e.retry_after_secs(), Some(1));
    }

    #[test]
    fn completion_and_chunk_shapes() {
        let u = Usage {
            prompt_tokens: 3,
            completion_tokens: 2,
            cached_tokens: 1,
            prefill_ms: 1.0,
            decode_ms: 2.0,
        };
        let c = completion_json("cmpl-1", "sm", 123, "hi", "length", &u);
        let j = Json::parse(&c.to_string()).unwrap();
        assert_eq!(j.get("object").unwrap().as_str(), Some("text_completion"));
        assert_eq!(
            j.get("choices").unwrap().as_arr().unwrap()[0].get("text").unwrap().as_str(),
            Some("hi")
        );
        assert_eq!(j.path("usage.total_tokens").unwrap().as_usize(), Some(5));
        assert_eq!(j.path("usage.cached_tokens").unwrap().as_usize(), Some(1));

        let mid = chunk_json("cmpl-1", "sm", 123, "h", None, None);
        let j = Json::parse(&mid.to_string()).unwrap();
        assert_eq!(j.get("object").unwrap().as_str(), Some("text_completion.chunk"));
        assert_eq!(
            j.get("choices").unwrap().as_arr().unwrap()[0].get("finish_reason").unwrap(),
            &Json::Null
        );

        let fin = chunk_json("cmpl-1", "sm", 123, "", Some("stop"), Some(&u));
        let j = Json::parse(&fin.to_string()).unwrap();
        assert_eq!(
            j.get("choices").unwrap().as_arr().unwrap()[0]
                .get("finish_reason")
                .unwrap()
                .as_str(),
            Some("stop")
        );
        assert_eq!(j.path("usage.prompt_tokens").unwrap().as_usize(), Some(3));
    }

    #[test]
    fn sse_framing() {
        let j = Json::obj().with("a", 1usize);
        assert_eq!(sse_event(&j), "data: {\"a\":1}\n\n");
        assert_eq!(sse_event_id(7, &j), "id: 7\ndata: {\"a\":1}\n\n");
        assert!(SSE_DONE.starts_with("data: [DONE]"));
    }
}
