//! HTTP/1.1 serving front-end over std::net + the in-tree threadpool
//! (tokio is unavailable offline).
//!
//! Endpoints (see README "Serving API"):
//!   GET  /health            -> {"status":"ok","model":...}
//!   GET  /healthz           -> liveness: 200 while the process answers
//!   GET  /readyz            -> readiness: 503 when draining, KV pool
//!                              over watermark, or the watchdog tripped
//!   GET  /metrics           -> text exposition (counters/gauges/latencies)
//!   POST /v1/completions    -> OpenAI-style completions; `"stream":true`
//!                              emits SSE chunks token-by-token
//!   POST /generate          -> legacy one-shot JSON (kept for old clients)
//!   POST /admin/drain       -> graceful drain: readiness off, admissions
//!                              stop, in-flight work finishes, clean exit
//!
//! SIGTERM triggers the same drain path as `/admin/drain`: in-flight
//! sequences finish (bounded by `drain_timeout_ms`), then the serve
//! loop exits cleanly.
//!
//! Connections are HTTP/1.1 keep-alive: one socket serves many requests
//! (SSE responses are close-delimited, so streams end the connection).
//! Requests funnel through a channel to the single engine thread (the
//! engine owns the PJRT client and block pool); each accepted request
//! becomes an engine *session* whose `SessionHandle` streams tokens
//! back to the connection thread. Dropped connections cancel their
//! session, which frees the sequence's KV blocks on the next step.

pub mod api;

use crate::engine::{Engine, FinishReason, GenRequest, HealthState, SessionEvent, SessionHandle};
use crate::model::tokenizer;
use crate::recovery::SessionMirror;
use crate::util::json::Json;
use crate::util::threadpool::{Channel, ThreadPool};
use anyhow::Result;
use api::ApiError;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

#[derive(Debug)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
    /// Client asked to reuse the socket (HTTP/1.1 default).
    pub keep_alive: bool,
    /// SSE resume cursor: the last event id the client saw on a
    /// previous stream of this resource (`Last-Event-ID` header).
    pub last_event_id: Option<u64>,
}

const KNOWN_METHODS: &[&str] = &["GET", "POST", "HEAD", "PUT", "DELETE", "OPTIONS", "PATCH"];

/// Parse one HTTP/1.1 request from a buffered stream.
///
/// `Ok(None)` is a clean end-of-stream (client closed between
/// requests). Errors carry the HTTP status the caller should answer
/// with: 405 for methods outside the HTTP verb set, 413 for bodies
/// over `api::MAX_BODY_BYTES` (never silently truncated), 400 for
/// everything malformed.
pub fn parse_request(reader: &mut impl BufRead) -> Result<Option<HttpRequest>, ApiError> {
    let mut line = String::new();
    match reader.read_line(&mut line) {
        Ok(0) => return Ok(None),
        Ok(_) => {}
        // Idle keep-alive socket hit the read timeout before sending a
        // request line: close it quietly so it stops pinning a worker.
        Err(e)
            if matches!(
                e.kind(),
                std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
            ) =>
        {
            return Ok(None)
        }
        Err(e) => return Err(ApiError::invalid_request(format!("read error: {e}"))),
    }
    if line.trim().is_empty() {
        return Ok(None);
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("/").to_string();
    let version = parts.next().unwrap_or("HTTP/1.1");
    if !KNOWN_METHODS.contains(&method.as_str()) {
        return Err(ApiError::method_not_allowed(&method));
    }
    let mut keep_alive = version != "HTTP/1.0";
    let mut content_length = 0usize;
    let mut last_event_id: Option<u64> = None;
    loop {
        let mut h = String::new();
        match reader.read_line(&mut h) {
            Ok(0) => break,
            Ok(_) => {}
            Err(e) => return Err(ApiError::invalid_request(format!("read error: {e}"))),
        }
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        let Some((name, value)) = h.split_once(':') else { continue };
        let value = value.trim();
        match name.to_ascii_lowercase().as_str() {
            "content-length" => {
                content_length = value
                    .parse()
                    .map_err(|_| ApiError::invalid_request("bad content-length"))?;
            }
            "connection" => {
                let v = value.to_ascii_lowercase();
                if v == "close" {
                    keep_alive = false;
                } else if v == "keep-alive" {
                    keep_alive = true;
                }
            }
            // Unparsable ids are ignored (the stream restarts from 0,
            // which is correct if duplicates are acceptable — and they
            // are, since event ids make replay idempotent client-side).
            "last-event-id" => last_event_id = value.parse().ok(),
            _ => {}
        }
    }
    if content_length > api::MAX_BODY_BYTES {
        return Err(ApiError::payload_too_large(content_length));
    }
    let mut body = vec![0u8; content_length];
    if content_length > 0 {
        reader
            .read_exact(&mut body)
            .map_err(|e| ApiError::invalid_request(format!("short body: {e}")))?;
    }
    Ok(Some(HttpRequest { method, path, body, keep_alive, last_event_id }))
}

fn status_reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        503 => "Service Unavailable",
        _ => "Internal Server Error",
    }
}

pub fn write_response(
    stream: &mut impl Write,
    status: u16,
    content_type: &str,
    body: &[u8],
    keep_alive: bool,
) -> Result<()> {
    write_response_with_headers(stream, status, content_type, body, keep_alive, &[])
}

/// `write_response` plus extra headers (e.g. `Retry-After` on
/// retryable rejections). With no extras the bytes are identical to
/// `write_response`.
pub fn write_response_with_headers(
    stream: &mut impl Write,
    status: u16,
    content_type: &str,
    body: &[u8],
    keep_alive: bool,
    extra: &[(&str, &str)],
) -> Result<()> {
    let conn = if keep_alive { "keep-alive" } else { "close" };
    write!(
        stream,
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: {conn}\r\n",
        status_reason(status),
        body.len()
    )?;
    for (name, value) in extra {
        write!(stream, "{name}: {value}\r\n")?;
    }
    write!(stream, "\r\n")?;
    stream.write_all(body)?;
    stream.flush()?;
    Ok(())
}

fn write_error(stream: &mut impl Write, e: &ApiError, keep_alive: bool) -> Result<()> {
    match e.retry_after_secs() {
        Some(secs) => {
            let v = secs.to_string();
            write_response_with_headers(
                stream,
                e.status,
                "application/json",
                e.body().as_bytes(),
                keep_alive,
                &[("Retry-After", v.as_str())],
            )
        }
        None => {
            write_response(stream, e.status, "application/json", e.body().as_bytes(), keep_alive)
        }
    }
}

/// What connection threads need; the engine itself stays on the
/// serving thread.
struct ServerCtx {
    queue: Channel<EngineMsg>,
    metrics: Arc<crate::metrics::Metrics>,
    cfg: crate::config::ServingConfig,
    model: String,
    /// Shared with the engine: readiness inputs + the drain flag.
    health: Arc<HealthState>,
    /// Journal-backed session mirror (`None` when `journal_dir` is
    /// unset): serves `/v1/sessions/{id}` and SSE stream resume.
    sessions: Option<SessionMirror>,
}

enum EngineMsg {
    Submit { req: GenRequest, reply: Channel<Result<SessionHandle, ApiError>> },
}

/// SIGTERM -> drain flag, without a libc dependency: `signal` comes
/// from the C runtime every binary already links.
#[cfg(unix)]
mod sigterm {
    use std::sync::atomic::{AtomicBool, Ordering};

    static RECEIVED: AtomicBool = AtomicBool::new(false);

    extern "C" fn on_sigterm(_sig: i32) {
        RECEIVED.store(true, Ordering::Release);
    }

    #[allow(clippy::fn_to_numeric_cast)]
    pub fn install() {
        const SIGTERM: i32 = 15;
        extern "C" {
            fn signal(sig: i32, handler: usize) -> usize;
        }
        unsafe {
            signal(SIGTERM, on_sigterm as usize);
        }
    }

    pub fn received() -> bool {
        RECEIVED.load(Ordering::Acquire)
    }
}

/// Serve until `stop` flips. Engine runs on the caller's thread;
/// connections are handled by a small pool.
pub fn serve(mut engine: Engine, addr: &str, stop: Arc<AtomicBool>) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    crate::info!("serving on http://{addr}");
    // Re-admit unfinished journaled sessions before opening the accept
    // loop: recovered decode continues exactly where the previous
    // process stopped, and clients re-attach via the resume API. The
    // report's handles stay alive for the life of the serve loop so
    // terminal events are never sent into a closed channel.
    let recovered = engine.recover();
    if !recovered.sessions.is_empty() {
        crate::info!(
            "recovered {} session(s) from the journal ({} tokens replayed)",
            recovered.sessions.len(),
            recovered.replayed_tokens
        );
    }
    let ctx = Arc::new(ServerCtx {
        queue: Channel::new(),
        metrics: engine.metrics.clone(),
        cfg: engine.cfg.clone(),
        model: engine.rt.config.name.clone(),
        health: engine.health.clone(),
        sessions: engine.journal_mirror(),
    });
    #[cfg(unix)]
    sigterm::install();
    let pool = ThreadPool::new(8, "http");
    let ctx2 = ctx.clone();
    let stop2 = stop.clone();
    let accept_thread = std::thread::spawn(move || {
        while !stop2.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((stream, _)) => {
                    let c = ctx2.clone();
                    pool.execute(move || handle_conn(stream, c));
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(e) => {
                    eprintln!("accept error: {e}");
                    break;
                }
            }
        }
        ctx2.queue.close();
    });

    // Engine loop: admit new sessions, then step. Token delivery and
    // completion flow through each session's handle, so the loop has no
    // per-request bookkeeping.
    let mut drain_started: Option<std::time::Instant> = None;
    while !stop.load(Ordering::Relaxed) {
        #[cfg(unix)]
        if sigterm::received() {
            ctx.health.begin_drain();
        }
        if ctx.health.draining() {
            // Graceful drain: readiness is already off and submit
            // rejects with 503; answer queued submits (so connection
            // threads unblock), finish in-flight work, then exit. The
            // deadline bounds a wedged sequence's hold on shutdown.
            let t0 = *drain_started.get_or_insert_with(|| {
                crate::info!("draining: admissions stopped, finishing in-flight work");
                std::time::Instant::now()
            });
            while let Some(msg) = ctx.queue.try_recv() {
                answer_submit(&mut engine, msg);
            }
            let deadline_hit = engine.cfg.drain_timeout_ms > 0
                && t0.elapsed()
                    >= std::time::Duration::from_millis(engine.cfg.drain_timeout_ms);
            if engine.idle() || deadline_hit {
                if deadline_hit && !engine.idle() {
                    engine.fail_all("server draining: drain deadline exceeded");
                }
                // Final checkpoint: a planned restart recovers with
                // zero journal replay.
                engine.checkpoint_now();
                engine
                    .metrics
                    .observe("drain_duration_ms", t0.elapsed().as_secs_f64() * 1e3);
                // Flip the shared stop flag so the accept thread (which
                // only watches `stop`) exits and `join` below returns.
                stop.store(true, Ordering::Relaxed);
                break;
            }
            if let Err(e) = engine.step() {
                engine.fail_all(&format!("engine error: {e}"));
            }
            continue;
        }
        // Drain ALL queued admissions (bounded by max_pending via
        // submit's rejection), then advance decode by one step.
        if engine.idle() {
            if let Some(msg) = ctx.queue.recv_timeout(std::time::Duration::from_millis(50)) {
                answer_submit(&mut engine, msg);
            }
        }
        while let Some(msg) = ctx.queue.try_recv() {
            answer_submit(&mut engine, msg);
        }
        if engine.idle() {
            continue;
        }
        if let Err(e) = engine.step() {
            // Per-sequence faults (panics, dispatch errors, KV
            // pressure) are contained inside `step` and never reach
            // here; an Err means the engine itself is broken, so this
            // is the true process-level shutdown path.
            engine.fail_all(&format!("engine error: {e}"));
        }
    }
    ctx.queue.close();
    // Answer any submit that raced with shutdown so no connection
    // thread is left blocking on its reply channel.
    while let Some(EngineMsg::Submit { reply, .. }) = ctx.queue.try_recv() {
        reply.send(Err(ApiError::unavailable("server shutting down")));
    }
    engine.fail_all("server shutting down");
    engine.checkpoint_now();
    let _ = accept_thread.join();
    drop(recovered);
    Ok(())
}

/// One connection: serve requests until the client closes, asks to, or
/// idles past `ServingConfig::keep_alive_idle_ms` (the worker pool is
/// small and fixed, so idle sockets must reclaim their threads).
fn handle_conn(stream: TcpStream, ctx: Arc<ServerCtx>) {
    let mut writer = stream;
    let idle = match ctx.cfg.keep_alive_idle_ms {
        0 => None, // wait forever
        ms => Some(std::time::Duration::from_millis(ms)),
    };
    let _ = writer.set_read_timeout(idle);
    let Ok(read_half) = writer.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    loop {
        let req = match parse_request(&mut reader) {
            Ok(Some(r)) => r,
            Ok(None) => break,
            Err(e) => {
                // Framing is unknown after a parse error: answer, close.
                let _ = write_error(&mut writer, &e, false);
                break;
            }
        };
        ctx.metrics.inc("http_requests");
        let client_keep = req.keep_alive;
        let server_keep = handle_request(&mut writer, req, &ctx).unwrap_or(false);
        if !(client_keep && server_keep) {
            break;
        }
    }
}

/// Route one request. Returns Ok(true) when the socket can be reused.
fn handle_request(
    stream: &mut TcpStream,
    req: HttpRequest,
    ctx: &ServerCtx,
) -> Result<bool> {
    const ROUTES: &[(&str, &str)] = &[
        ("GET", "/health"),
        ("GET", "/healthz"),
        ("GET", "/readyz"),
        ("GET", "/metrics"),
        ("POST", "/v1/completions"),
        ("POST", "/generate"),
        ("POST", "/admin/drain"),
    ];
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/health") => {
            let body = Json::obj()
                .with("status", "ok")
                .with("model", ctx.model.as_str())
                .to_string();
            write_response(stream, 200, "application/json", body.as_bytes(), true)?;
            Ok(true)
        }
        ("GET", "/healthz") => {
            // Liveness: the process is up and answering requests.
            let body = Json::obj().with("status", "ok").to_string();
            write_response(stream, 200, "application/json", body.as_bytes(), true)?;
            Ok(true)
        }
        ("GET", "/readyz") => {
            let ready = ctx.health.ready();
            let body = Json::obj()
                .with("ready", ready)
                .with("draining", ctx.health.draining())
                .to_string();
            let status = if ready { 200 } else { 503 };
            write_response(stream, status, "application/json", body.as_bytes(), true)?;
            Ok(true)
        }
        ("POST", "/admin/drain") => {
            ctx.health.begin_drain();
            ctx.metrics.inc("drain_requests");
            let body = Json::obj().with("draining", true).to_string();
            write_response(stream, 200, "application/json", body.as_bytes(), true)?;
            Ok(true)
        }
        ("GET", "/metrics") => {
            let body = ctx.metrics.render();
            write_response(stream, 200, "text/plain", body.as_bytes(), true)?;
            Ok(true)
        }
        ("POST", "/v1/completions") => handle_completions(stream, &req.body, ctx),
        ("POST", "/generate") => handle_generate_legacy(stream, &req.body, ctx),
        (m, p) if p.starts_with("/v1/sessions/") => {
            handle_session_route(stream, m, p, req.last_event_id, ctx)
        }
        (m, p) if ROUTES.iter().any(|&(_, rp)| rp == p) => {
            write_error(stream, &ApiError::method_not_allowed(m), true)?;
            Ok(true)
        }
        (_, p) => {
            write_error(stream, &ApiError::not_found(p), true)?;
            Ok(true)
        }
    }
}

/// `GET /v1/sessions/{id}` (journaled status) and
/// `GET /v1/sessions/{id}/stream` (SSE replay with `Last-Event-ID`
/// resume). Both are served from the journal's in-memory mirror, so
/// they work for live sessions, finished-but-retained sessions, and
/// sessions recovered after a crash. 404 when journaling is disabled.
fn handle_session_route(
    stream: &mut TcpStream,
    method: &str,
    path: &str,
    last_event_id: Option<u64>,
    ctx: &ServerCtx,
) -> Result<bool> {
    if method != "GET" {
        write_error(stream, &ApiError::method_not_allowed(method), true)?;
        return Ok(true);
    }
    let Some(sessions) = &ctx.sessions else {
        write_error(stream, &ApiError::not_found(path), true)?;
        return Ok(true);
    };
    let rest = &path["/v1/sessions/".len()..];
    let (id_str, want_stream) = match rest.strip_suffix("/stream") {
        Some(s) => (s, true),
        None => (rest, false),
    };
    let Ok(id) = id_str.parse::<u64>() else {
        write_error(stream, &ApiError::not_found(path), true)?;
        return Ok(true);
    };
    let Some(st) = sessions.get(id) else {
        write_error(stream, &ApiError::not_found(path), true)?;
        return Ok(true);
    };
    if want_stream {
        return stream_session_replay(stream, ctx, sessions, id, last_event_id);
    }
    let status = st.finish.map(|t| t.as_str()).unwrap_or("active");
    let body = Json::obj()
        .with("id", id as i64)
        .with("status", status)
        .with("prompt_tokens", st.admit.prompt.len())
        .with("tokens", st.tokens.len())
        .with("text", tokenizer::decode(&st.tokens).as_str())
        .to_string();
    write_response(stream, 200, "application/json", body.as_bytes(), true)?;
    Ok(true)
}

/// SSE replay of a journaled session: frames every token past the
/// client's `Last-Event-ID` cursor immediately, then follows the live
/// mirror until the session reaches a terminal state (or no progress
/// happens for ~30 s). Event ids are 0-based token indices, so a
/// client reconnecting with `Last-Event-ID: n` receives token n+1
/// onward — no gaps, no duplicates.
fn stream_session_replay(
    stream: &mut TcpStream,
    ctx: &ServerCtx,
    sessions: &SessionMirror,
    id: u64,
    last_event_id: Option<u64>,
) -> Result<bool> {
    if stream
        .write_all(
            b"HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\nConnection: close\r\n\r\n",
        )
        .and_then(|_| stream.flush())
        .is_err()
    {
        ctx.metrics.inc("stream_disconnects");
        return Ok(false);
    }
    let rid = format!("cmpl-{id}");
    let created = api::unix_now();
    // Index of the next token to send.
    let mut cursor = last_event_id.map(|n| n as usize + 1).unwrap_or(0);
    let mut pending_bytes: Vec<u8> = Vec::new();
    let idle_cap = std::time::Duration::from_secs(30);
    let mut last_progress = std::time::Instant::now();
    loop {
        let Some(st) = sessions.get(id) else { break };
        let mut wrote = false;
        while cursor < st.tokens.len() {
            pending_bytes.push(st.tokens[cursor].clamp(0, 255) as u8); // byte-level vocab
            let text = take_utf8_prefix(&mut pending_bytes);
            let frame = api::sse_event_id(
                cursor as u64,
                &api::chunk_json(&rid, &ctx.model, created, &text, None, None),
            );
            if stream.write_all(frame.as_bytes()).is_err() {
                ctx.metrics.inc("stream_disconnects");
                return Ok(false);
            }
            cursor += 1;
            wrote = true;
        }
        if wrote {
            let _ = stream.flush();
            last_progress = std::time::Instant::now();
        }
        if let Some(fin) = st.finish {
            let tail = if pending_bytes.is_empty() {
                String::new()
            } else {
                String::from_utf8_lossy(&pending_bytes).into_owned()
            };
            let frame = api::sse_event(&api::chunk_json(
                &rid,
                &ctx.model,
                created,
                &tail,
                Some(fin.as_str()),
                None,
            ));
            let _ = stream
                .write_all(frame.as_bytes())
                .and_then(|_| stream.write_all(api::SSE_DONE.as_bytes()))
                .and_then(|_| stream.flush());
            break;
        }
        if last_progress.elapsed() >= idle_cap {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    Ok(false)
}

/// Run one submit on the engine and deliver the handle. If the
/// requester gave up (reply channel closed), cancel the session so the
/// engine doesn't decode for nobody.
fn answer_submit(engine: &mut Engine, msg: EngineMsg) {
    let EngineMsg::Submit { req, reply } = msg;
    let res = engine.submit(req).map_err(ApiError::from);
    if let Some(unclaimed) = reply.send_or_return(res) {
        if let Ok(handle) = unclaimed {
            handle.cancel();
        }
    }
}

/// Submit through the engine thread and wait for the session handle.
/// The timeout (`ServingConfig::reply_timeout_ms`, 0 = wait forever)
/// is a shutdown-race backstop: the engine loop answers within one
/// step in normal operation.
fn open_session(ctx: &ServerCtx, req: GenRequest) -> Result<SessionHandle, ApiError> {
    let reply: Channel<Result<SessionHandle, ApiError>> = Channel::new();
    if !ctx.queue.send(EngineMsg::Submit { req, reply: reply.clone() }) {
        return Err(ApiError::unavailable("server shutting down"));
    }
    let got = match ctx.cfg.reply_timeout_ms {
        0 => reply.recv(),
        ms => reply.recv_timeout(std::time::Duration::from_millis(ms)),
    };
    match got {
        Some(r) => r,
        None => {
            // Stop waiting; reclaim (and cancel) a handle that may have
            // been delivered in the race window.
            reply.close();
            if let Some(Ok(handle)) = reply.try_recv() {
                handle.cancel();
            }
            Err(ApiError::unavailable("engine did not respond"))
        }
    }
}

fn handle_completions(stream: &mut TcpStream, body: &[u8], ctx: &ServerCtx) -> Result<bool> {
    let parsed = std::str::from_utf8(body)
        .map_err(|_| ApiError::invalid_request("body is not UTF-8"))
        .and_then(|s| {
            Json::parse(s).map_err(|e| ApiError::invalid_request(format!("invalid json: {e}")))
        })
        .and_then(|j| api::CompletionRequest::from_json(&j));
    let creq = match parsed {
        Ok(c) => c,
        Err(e) => {
            write_error(stream, &e, true)?;
            return Ok(true);
        }
    };
    let gen = match creq.to_gen_request(&ctx.cfg) {
        Ok(g) => g,
        Err(e) => {
            write_error(stream, &e, true)?;
            return Ok(true);
        }
    };
    let handle = match open_session(ctx, gen) {
        Ok(h) => h,
        Err(e) => {
            write_error(stream, &e, true)?;
            return Ok(true);
        }
    };
    let id = format!("cmpl-{}", handle.id);
    let created = api::unix_now();
    if creq.stream {
        stream_completion(stream, ctx, &handle, &id, created)
    } else {
        let out = handle.collect();
        if let Some(e) = out.error {
            write_error(stream, &ApiError::from_session_failure(&e), true)?;
            return Ok(true);
        }
        if out.finish == Some(FinishReason::Timeout) && out.tokens.is_empty() {
            // Deadline hit before any token: a clean 408. Partial
            // results still return 200 with finish_reason "timeout".
            write_error(stream, &ApiError::request_timeout("deadline exceeded"), true)?;
            return Ok(true);
        }
        let text = tokenizer::decode(&out.tokens);
        let finish = out.finish.map(|f| f.as_str()).unwrap_or("length");
        let usage = out.usage.unwrap_or_default();
        let body = api::completion_json(&id, &ctx.model, created, &text, finish, &usage);
        write_response(stream, 200, "application/json", body.to_string().as_bytes(), true)?;
        Ok(true)
    }
}

/// Incremental UTF-8 reassembly for the byte-level token stream:
/// returns the longest cleanly-decodable prefix of `buf` (invalid
/// sequences become U+FFFD), leaving an incomplete trailing sequence
/// buffered for the next token. Without this, a multi-byte character
/// split across token chunks would decode to replacement characters
/// and streamed text would diverge from the non-streaming response.
fn take_utf8_prefix(buf: &mut Vec<u8>) -> String {
    let mut out = String::new();
    loop {
        match std::str::from_utf8(buf) {
            Ok(s) => {
                out.push_str(s);
                buf.clear();
                return out;
            }
            Err(e) => {
                let valid = e.valid_up_to();
                out.push_str(std::str::from_utf8(&buf[..valid]).unwrap());
                match e.error_len() {
                    Some(bad) => {
                        out.push('\u{fffd}');
                        buf.drain(..valid + bad);
                    }
                    None => {
                        buf.drain(..valid);
                        return out;
                    }
                }
            }
        }
    }
}

/// Token-by-token SSE. The response is close-delimited (no
/// Content-Length), so this always ends the connection. A failed
/// write means the client went away: cancel the session so the engine
/// frees its blocks on the next step.
fn stream_completion(
    stream: &mut TcpStream,
    ctx: &ServerCtx,
    handle: &SessionHandle,
    id: &str,
    created: u64,
) -> Result<bool> {
    if stream
        .write_all(
            b"HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\nConnection: close\r\n\r\n",
        )
        .and_then(|_| stream.flush())
        .is_err()
    {
        handle.cancel();
        ctx.metrics.inc("stream_disconnects");
        return Ok(false);
    }
    let mut pending_bytes: Vec<u8> = Vec::new();
    loop {
        let Some(ev) = handle.recv() else { break };
        let frame = match ev {
            SessionEvent::Token { token, index, .. } => {
                pending_bytes.push(token.clamp(0, 255) as u8); // byte-level vocab
                let text = take_utf8_prefix(&mut pending_bytes);
                // Id-carrying frames make `Last-Event-ID` resume
                // meaningful after a dropped live stream.
                api::sse_event_id(
                    index as u64,
                    &api::chunk_json(id, &ctx.model, created, &text, None, None),
                )
            }
            SessionEvent::Done { usage, finish } => {
                // Flush any buffered partial character into the
                // terminal chunk (lossily: the stream is over).
                let tail = if pending_bytes.is_empty() {
                    String::new()
                } else {
                    String::from_utf8_lossy(&pending_bytes).into_owned()
                };
                let fin = api::sse_event(&api::chunk_json(
                    id,
                    &ctx.model,
                    created,
                    &tail,
                    Some(finish.as_str()),
                    Some(&usage),
                ));
                let _ = stream
                    .write_all(fin.as_bytes())
                    .and_then(|_| stream.write_all(api::SSE_DONE.as_bytes()))
                    .and_then(|_| stream.flush());
                break;
            }
            SessionEvent::Error(e) => {
                let _ = stream
                    .write_all(api::sse_event(&Json::obj().with(
                        "error",
                        Json::obj().with("type", "internal_error").with("message", e),
                    ))
                    .as_bytes())
                    .and_then(|_| stream.flush());
                break;
            }
        };
        if stream.write_all(frame.as_bytes()).and_then(|_| stream.flush()).is_err() {
            // Client disconnected mid-stream.
            handle.cancel();
            ctx.metrics.inc("stream_disconnects");
            break;
        }
    }
    Ok(false)
}

/// Pre-`/v1` response shape, now served through a session internally.
fn handle_generate_legacy(stream: &mut TcpStream, body: &[u8], ctx: &ServerCtx) -> Result<bool> {
    let parsed = std::str::from_utf8(body).ok().and_then(|s| Json::parse(s).ok());
    let Some(j) = parsed else {
        write_error(stream, &ApiError::invalid_request("invalid json"), true)?;
        return Ok(true);
    };
    let Some(prompt) = j.get("prompt").and_then(Json::as_str) else {
        write_error(stream, &ApiError::invalid_request("missing prompt"), true)?;
        return Ok(true);
    };
    let max_new = j.get("max_new_tokens").and_then(Json::as_usize).unwrap_or(64);
    let gen = GenRequest::new(tokenizer::encode(prompt), max_new);
    let handle = match open_session(ctx, gen) {
        Ok(h) => h,
        Err(e) => {
            write_error(stream, &e, true)?;
            return Ok(true);
        }
    };
    let out = handle.collect();
    if let Some(e) = out.error {
        write_error(stream, &ApiError::from_session_failure(&e), true)?;
        return Ok(true);
    }
    if out.finish == Some(FinishReason::Timeout) && out.tokens.is_empty() {
        write_error(stream, &ApiError::request_timeout("deadline exceeded"), true)?;
        return Ok(true);
    }
    let usage = out.usage.unwrap_or_default();
    let body = Json::obj()
        .with("text", tokenizer::decode(&out.tokens))
        .with("tokens", out.tokens.len())
        .with("cached_tokens", usage.cached_tokens)
        .with("prefill_ms", usage.prefill_ms)
        .with("decode_ms", usage.decode_ms);
    write_response(stream, 200, "application/json", body.to_string().as_bytes(), true)?;
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(raw: &[u8]) -> Result<Option<HttpRequest>, ApiError> {
        parse_request(&mut Cursor::new(raw.to_vec()))
    }

    #[test]
    fn parse_post_with_body() {
        let raw = b"POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: 13\r\n\r\n{\"prompt\":\"a\"}";
        let r = parse(raw).unwrap().unwrap();
        assert_eq!(r.method, "POST");
        assert_eq!(r.path, "/generate");
        assert_eq!(r.body.len(), 13);
        assert!(r.keep_alive, "HTTP/1.1 defaults to keep-alive");
    }

    #[test]
    fn parse_get_no_body() {
        let raw = b"GET /health HTTP/1.1\r\n\r\n";
        let r = parse(raw).unwrap().unwrap();
        assert_eq!(r.method, "GET");
        assert_eq!(r.path, "/health");
        assert!(r.body.is_empty());
    }

    #[test]
    fn parse_eof_is_clean_close() {
        assert!(parse(b"").unwrap().is_none());
    }

    #[test]
    fn unknown_method_is_405() {
        let e = parse(b"BREW /coffee HTTP/1.1\r\n\r\n").unwrap_err();
        assert_eq!(e.status, 405);
        assert!(e.message.contains("BREW"));
    }

    #[test]
    fn oversized_body_is_413_not_truncated() {
        let raw = format!(
            "POST /v1/completions HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
            api::MAX_BODY_BYTES + 1
        );
        let e = parse(raw.as_bytes()).unwrap_err();
        assert_eq!(e.status, 413);
        // Exactly at the limit is still accepted framing-wise (the body
        // itself is missing here, which is a 400 short-read instead).
        let raw = format!(
            "POST /v1/completions HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
            api::MAX_BODY_BYTES
        );
        let e = parse(raw.as_bytes()).unwrap_err();
        assert_eq!(e.status, 400);
    }

    #[test]
    fn bad_content_length_is_400() {
        let e = parse(b"POST /x HTTP/1.1\r\nContent-Length: nope\r\n\r\n").unwrap_err();
        assert_eq!(e.status, 400);
    }

    #[test]
    fn connection_close_header_disables_keep_alive() {
        let r = parse(b"GET /health HTTP/1.1\r\nConnection: close\r\n\r\n")
            .unwrap()
            .unwrap();
        assert!(!r.keep_alive);
        let r = parse(b"GET /health HTTP/1.0\r\n\r\n").unwrap().unwrap();
        assert!(!r.keep_alive, "HTTP/1.0 defaults to close");
        let r = parse(b"GET /health HTTP/1.0\r\nConnection: keep-alive\r\n\r\n")
            .unwrap()
            .unwrap();
        assert!(r.keep_alive);
    }

    #[test]
    fn last_event_id_header_parses() {
        let r = parse(b"GET /v1/sessions/3/stream HTTP/1.1\r\nLast-Event-ID: 41\r\n\r\n")
            .unwrap()
            .unwrap();
        assert_eq!(r.last_event_id, Some(41));
        // Case-insensitive, like every other header.
        let r = parse(b"GET /x HTTP/1.1\r\nlast-event-id: 7\r\n\r\n").unwrap().unwrap();
        assert_eq!(r.last_event_id, Some(7));
        // Garbage ids are ignored, not fatal: replay restarts from 0.
        let r = parse(b"GET /x HTTP/1.1\r\nLast-Event-ID: nope\r\n\r\n").unwrap().unwrap();
        assert_eq!(r.last_event_id, None);
        let r = parse(b"GET /x HTTP/1.1\r\n\r\n").unwrap().unwrap();
        assert_eq!(r.last_event_id, None);
    }

    #[test]
    fn two_requests_on_one_buffered_stream() {
        let raw = b"GET /health HTTP/1.1\r\n\r\nGET /metrics HTTP/1.1\r\n\r\n";
        let mut cursor = Cursor::new(raw.to_vec());
        let a = parse_request(&mut cursor).unwrap().unwrap();
        let b = parse_request(&mut cursor).unwrap().unwrap();
        assert_eq!(a.path, "/health");
        assert_eq!(b.path, "/metrics");
        assert!(parse_request(&mut cursor).unwrap().is_none());
    }

    #[test]
    fn response_format() {
        let mut out = Vec::new();
        write_response(&mut out, 200, "application/json", b"{}", true).unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(s.contains("Content-Length: 2"));
        assert!(s.contains("Connection: keep-alive"));
        assert!(s.ends_with("{}"));

        let mut out = Vec::new();
        write_response(&mut out, 429, "application/json", b"{}", false).unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 429 Too Many Requests\r\n"));
        assert!(s.contains("Connection: close"));
    }

    #[test]
    fn retryable_errors_carry_retry_after_header() {
        let mut out = Vec::new();
        let e = ApiError::overloaded("rate limited").with_retry_after(2500);
        write_error(&mut out, &e, true).unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 429"));
        assert!(s.contains("Retry-After: 3\r\n"), "2500 ms rounds up to 3 s: {s}");
        assert!(s.ends_with(e.body().as_str()), "header goes before the body");

        let mut out = Vec::new();
        write_error(&mut out, &ApiError::internal("boom"), true).unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(!s.contains("Retry-After"), "non-retryable errors carry no hint");
    }
}
