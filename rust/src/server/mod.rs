//! Minimal HTTP/1.1 serving front-end over std::net + the in-tree
//! threadpool (tokio is unavailable offline).
//!
//! Endpoints:
//!   GET  /health            -> {"status":"ok", ...}
//!   GET  /metrics           -> text exposition
//!   POST /generate          -> {"prompt": str, "max_new_tokens": n,
//!                               "temperature"?: f, "greedy"?: b}
//!                           <- {"text": str, "tokens": n, latency fields}
//!
//! Requests are funneled through a channel to the single engine thread
//! (the engine owns the PJRT client and block pool); responses return
//! through per-request channels — the standard leader/worker shape.

use crate::engine::{Engine, GenRequest};
use crate::metrics::Metrics;
use crate::model::tokenizer;
use crate::util::json::Json;
use crate::util::threadpool::{Channel, ThreadPool};
use anyhow::Result;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
}

/// Parse one HTTP/1.1 request from the stream.
pub fn parse_request(stream: &mut impl Read) -> Result<HttpRequest> {
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("/").to_string();
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some(v) = h.to_ascii_lowercase().strip_prefix("content-length:") {
            content_length = v.trim().parse().unwrap_or(0);
        }
    }
    let mut body = vec![0u8; content_length.min(16 << 20)];
    if content_length > 0 {
        reader.read_exact(&mut body)?;
    }
    Ok(HttpRequest { method, path, body })
}

pub fn write_response(stream: &mut impl Write, status: u16, content_type: &str, body: &[u8]) -> Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        _ => "Internal Server Error",
    };
    write!(
        stream,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    )?;
    stream.write_all(body)?;
    Ok(())
}

/// A pending generation: request + response channel.
struct Pending {
    req: GenRequest,
    reply: Channel<Result<Json, String>>,
}

/// Serve until `stop` flips. Engine runs on the caller's thread;
/// connections are handled by a small pool.
pub fn serve(mut engine: Engine, addr: &str, stop: Arc<AtomicBool>) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    crate::info!("serving on http://{addr}");
    let queue: Channel<Pending> = Channel::new();
    let metrics = engine.metrics.clone();
    let pool = ThreadPool::new(4, "http");
    let q2 = queue.clone();
    let m2 = metrics.clone();
    let stop2 = stop.clone();
    let accept_thread = std::thread::spawn(move || {
        while !stop2.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((stream, _)) => {
                    let q = q2.clone();
                    let m = m2.clone();
                    pool.execute(move || handle_conn(stream, q, m));
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(e) => {
                    eprintln!("accept error: {e}");
                    break;
                }
            }
        }
        q2.close();
    });

    // Engine loop: drain admissions, then step active sequences.
    let mut inflight: Vec<(crate::engine::SeqId, Channel<Result<Json, String>>)> = Vec::new();
    while !stop.load(Ordering::Relaxed) {
        // Admit pending requests (non-blocking when busy, blocking briefly when idle).
        let next = if inflight.is_empty() {
            queue.recv_timeout(std::time::Duration::from_millis(50))
        } else {
            queue.try_recv()
        };
        if let Some(p) = next {
            match engine.add(p.req) {
                Ok(id) => inflight.push((id, p.reply)),
                Err(e) => {
                    p.reply.send(Err(format!("admission failed: {e}")));
                }
            }
        }
        if inflight.is_empty() {
            continue;
        }
        if let Err(e) = engine.step() {
            for (_, reply) in inflight.drain(..) {
                reply.send(Err(format!("engine error: {e}")));
            }
            continue;
        }
        // Complete finished sequences.
        let done: Vec<_> = engine.finished();
        for id in done {
            if let Some(pos) = inflight.iter().position(|(i, _)| *i == id) {
                let (_, reply) = inflight.remove(pos);
                let res = engine.remove(id).unwrap();
                let text = tokenizer::decode(&res.tokens[res.tokens.len() - res.logprobs.len()..]);
                let j = Json::obj()
                    .with("text", text)
                    .with("tokens", res.logprobs.len())
                    .with("prefill_ms", res.prefill_ms)
                    .with("decode_ms", res.decode_ms);
                reply.send(Ok(j));
            } else {
                engine.remove(id);
            }
        }
    }
    queue.close();
    let _ = accept_thread.join();
    Ok(())
}

fn handle_conn(mut stream: TcpStream, queue: Channel<Pending>, metrics: Arc<Metrics>) {
    let req = match parse_request(&mut stream) {
        Ok(r) => r,
        Err(_) => return,
    };
    metrics.inc("http_requests");
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/health") => {
            let body = Json::obj().with("status", "ok").to_string();
            let _ = write_response(&mut stream, 200, "application/json", body.as_bytes());
        }
        ("GET", "/metrics") => {
            let body = metrics.render();
            let _ = write_response(&mut stream, 200, "text/plain", body.as_bytes());
        }
        ("POST", "/generate") => {
            let parsed = std::str::from_utf8(&req.body)
                .ok()
                .and_then(|s| Json::parse(s).ok());
            let Some(j) = parsed else {
                let _ = write_response(&mut stream, 400, "application/json",
                    br#"{"error":"invalid json"}"#);
                return;
            };
            let Some(prompt) = j.get("prompt").and_then(Json::as_str) else {
                let _ = write_response(&mut stream, 400, "application/json",
                    br#"{"error":"missing prompt"}"#);
                return;
            };
            let max_new = j.get("max_new_tokens").and_then(Json::as_usize).unwrap_or(64);
            let gen = GenRequest::new(tokenizer::encode(prompt), max_new);
            let reply: Channel<Result<Json, String>> = Channel::new();
            queue.send(Pending { req: gen, reply: reply.clone() });
            match reply.recv() {
                Some(Ok(body)) => {
                    let _ = write_response(&mut stream, 200, "application/json",
                        body.to_string().as_bytes());
                }
                Some(Err(e)) => {
                    let body = Json::obj().with("error", e).to_string();
                    let _ = write_response(&mut stream, 500, "application/json", body.as_bytes());
                }
                None => {
                    let _ = write_response(&mut stream, 500, "application/json",
                        br#"{"error":"server shutting down"}"#);
                }
            }
        }
        _ => {
            let _ = write_response(&mut stream, 404, "application/json",
                br#"{"error":"not found"}"#);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_post_with_body() {
        let raw = b"POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: 13\r\n\r\n{\"prompt\":\"a\"}";
        let mut cursor = std::io::Cursor::new(raw.to_vec());
        let r = parse_request(&mut cursor).unwrap();
        assert_eq!(r.method, "POST");
        assert_eq!(r.path, "/generate");
        assert_eq!(r.body.len(), 13);
    }

    #[test]
    fn parse_get_no_body() {
        let raw = b"GET /health HTTP/1.1\r\n\r\n";
        let mut cursor = std::io::Cursor::new(raw.to_vec());
        let r = parse_request(&mut cursor).unwrap();
        assert_eq!(r.method, "GET");
        assert_eq!(r.path, "/health");
        assert!(r.body.is_empty());
    }

    #[test]
    fn response_format() {
        let mut out = Vec::new();
        write_response(&mut out, 200, "application/json", b"{}").unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(s.contains("Content-Length: 2"));
        assert!(s.ends_with("{}"));
    }
}
