//! Infrastructure substrates built in-tree (the usual crates — tokio,
//! serde, clap, criterion, proptest — are unavailable offline).

pub mod cli;
pub mod fsio;
pub mod json;
pub mod minitest;
pub mod prng;
pub mod stats;
pub mod threadpool;

use std::sync::atomic::{AtomicU8, Ordering};

static LOG_LEVEL: AtomicU8 = AtomicU8::new(1); // 0=quiet 1=info 2=debug

pub fn set_log_level(level: u8) {
    LOG_LEVEL.store(level, Ordering::Relaxed);
}

pub fn log_enabled(level: u8) -> bool {
    LOG_LEVEL.load(Ordering::Relaxed) >= level
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        if $crate::util::log_enabled(1) {
            eprintln!("[info] {}", format!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        if $crate::util::log_enabled(2) {
            eprintln!("[debug] {}", format!($($arg)*));
        }
    };
}
