//! Deterministic PRNGs (no `rand` offline).
//!
//! `SplitMix64` is bit-for-bit identical to `python/compile/data.py`'s
//! implementation — workload generators on both sides must agree so the
//! rust harness can regenerate the corpora/tasks python trained on.

/// SplitMix64 (Steele et al.); passes BigCrush, 8 bytes of state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform integer in `[0, n)`. Matches the python side's `below`
    /// (plain modulo — bias is irrelevant for workload generation and
    /// parity matters more).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Standard normal via Box–Muller.
    pub fn next_gaussian(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len() as u64) as usize]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            items.swap(i, j);
        }
    }

    /// `k` distinct indices from `[0, n)` (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pinned_values_match_python() {
        // Same constants asserted in python/tests/test_data.py.
        let mut r = SplitMix64::new(0);
        assert_eq!(r.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(r.next_u64(), 0x6E78_9E6A_A1B9_65F4);
    }

    #[test]
    fn below_in_range() {
        let mut r = SplitMix64::new(7);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SplitMix64::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = SplitMix64::new(11);
        let xs: Vec<f64> = (0..20_000).map(|_| r.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = SplitMix64::new(9);
        let s = r.sample_indices(20, 10);
        assert_eq!(s.len(), 10);
        let set: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(set.len(), 10);
    }
}
