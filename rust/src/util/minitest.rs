//! Mini property-testing harness (proptest is unavailable offline).
//!
//! `check(seed, cases, gen, prop)` runs `prop` on `cases` generated
//! inputs; on failure it performs greedy shrinking via the input's
//! `Shrink` implementation and panics with the minimal counterexample.
//! Coordinator invariants (paged allocator, radar index, batcher)
//! use this for their property tests.

use super::prng::SplitMix64;
use std::fmt::Debug;

/// Types that can propose smaller versions of themselves.
pub trait Shrink: Sized {
    /// Candidate smaller inputs; empty when fully shrunk.
    fn shrink(&self) -> Vec<Self>;
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        if *self == 0 {
            vec![]
        } else {
            vec![0, self / 2, self - 1]
        }
    }
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<Self> {
        if *self == 0 {
            vec![]
        } else {
            vec![0, self / 2, self - 1]
        }
    }
}

impl<T: Shrink + Clone> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        // Drop halves, drop single elements, shrink single elements.
        out.push(self[..self.len() / 2].to_vec());
        out.push(self[self.len() / 2..].to_vec());
        if self.len() <= 16 {
            for i in 0..self.len() {
                let mut v = self.clone();
                v.remove(i);
                out.push(v);
            }
            for i in 0..self.len() {
                for s in self[i].shrink() {
                    let mut v = self.clone();
                    v[i] = s;
                    out.push(v);
                }
            }
        }
        out
    }
}

impl<A: Shrink + Clone, B: Shrink + Clone> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> =
            self.0.shrink().into_iter().map(|a| (a, self.1.clone())).collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// Run a property over generated inputs with shrinking on failure.
///
/// `gen` draws an input from the PRNG; `prop` returns `Err(reason)` on
/// violation. Panics with the (shrunk) counterexample.
pub fn check<T, G, P>(seed: u64, cases: usize, mut gen: G, prop: P)
where
    T: Shrink + Clone + Debug,
    G: FnMut(&mut SplitMix64) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    let mut rng = SplitMix64::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            let (min_input, min_msg) = shrink_loop(input, msg, &prop);
            panic!(
                "property failed (case {case}, seed {seed}): {min_msg}\n\
                 minimal counterexample: {min_input:?}"
            );
        }
    }
}

fn shrink_loop<T: Shrink + Clone + Debug>(
    mut input: T,
    mut msg: String,
    prop: &impl Fn(&T) -> Result<(), String>,
) -> (T, String) {
    let mut budget = 500;
    'outer: while budget > 0 {
        for cand in input.shrink() {
            budget -= 1;
            if let Err(m) = prop(&cand) {
                input = cand;
                msg = m;
                continue 'outer;
            }
            if budget == 0 {
                break;
            }
        }
        break;
    }
    (input, msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_is_quiet() {
        check(1, 100, |r| r.below(100) as usize, |x| {
            if *x < 100 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "minimal counterexample")]
    fn failing_property_panics() {
        check(2, 100, |r| r.below(1000) as usize, |x| {
            if *x < 500 {
                Ok(())
            } else {
                Err(format!("{x} too big"))
            }
        });
    }

    #[test]
    fn shrinking_finds_small_counterexample() {
        // Property fails for any x >= 10; shrinker should reach exactly 10.
        let result = std::panic::catch_unwind(|| {
            check(3, 200, |r| r.below(10_000) as usize, |x| {
                if *x < 10 {
                    Ok(())
                } else {
                    Err("ge 10".into())
                }
            });
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("counterexample: 10"), "got: {msg}");
    }

    #[test]
    fn vec_shrink_reduces_len() {
        let v = vec![5usize, 6, 7, 8];
        assert!(v.shrink().iter().any(|s| s.len() < v.len()));
    }
}
