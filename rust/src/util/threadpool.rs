//! Thread pool + mpmc channel substrate (tokio is unavailable offline).
//!
//! The serving stack is thread-based: the HTTP server and the engine
//! loop exchange work through `Channel<T>` (a Mutex+Condvar mpmc queue)
//! and blocking sections run on `ThreadPool` workers. On this 1-core
//! box the pool mostly provides isolation, not parallelism — but the
//! architecture is the standard leader/worker shape.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Unbounded mpmc channel. `recv` blocks; `try_recv` doesn't.
/// Closing wakes all receivers, which then drain and get `None`.
pub struct Channel<T> {
    inner: Arc<ChannelInner<T>>,
}

struct ChannelInner<T> {
    queue: Mutex<VecDeque<T>>,
    cond: Condvar,
    closed: AtomicBool,
}

impl<T> Clone for Channel<T> {
    fn clone(&self) -> Self {
        Self { inner: self.inner.clone() }
    }
}

impl<T> Default for Channel<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> Channel<T> {
    pub fn new() -> Self {
        Self {
            inner: Arc::new(ChannelInner {
                queue: Mutex::new(VecDeque::new()),
                cond: Condvar::new(),
                closed: AtomicBool::new(false),
            }),
        }
    }

    pub fn send(&self, item: T) -> bool {
        self.send_or_return(item).is_none()
    }

    /// Like `send`, but hands the item back instead of dropping it when
    /// the channel is closed — for senders that must dispose of it
    /// deliberately (e.g. cancelling a session handle the receiver
    /// will never collect). The closed check runs under the queue lock,
    /// so a `close(); try_recv()` receiver either drains the item or
    /// the sender gets it back; it is never silently lost.
    pub fn send_or_return(&self, item: T) -> Option<T> {
        let mut q = self.inner.queue.lock().unwrap();
        if self.inner.closed.load(Ordering::Acquire) {
            return Some(item);
        }
        q.push_back(item);
        drop(q);
        self.inner.cond.notify_one();
        None
    }

    pub fn recv(&self) -> Option<T> {
        let mut q = self.inner.queue.lock().unwrap();
        loop {
            if let Some(item) = q.pop_front() {
                return Some(item);
            }
            if self.inner.closed.load(Ordering::Acquire) {
                return None;
            }
            q = self.inner.cond.wait(q).unwrap();
        }
    }

    pub fn try_recv(&self) -> Option<T> {
        self.inner.queue.lock().unwrap().pop_front()
    }

    pub fn recv_timeout(&self, timeout: std::time::Duration) -> Option<T> {
        let deadline = std::time::Instant::now() + timeout;
        let mut q = self.inner.queue.lock().unwrap();
        loop {
            if let Some(item) = q.pop_front() {
                return Some(item);
            }
            if self.inner.closed.load(Ordering::Acquire) {
                return None;
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _res) =
                self.inner.cond.wait_timeout(q, deadline - now).unwrap();
            q = guard;
        }
    }

    pub fn len(&self) -> usize {
        self.inner.queue.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn close(&self) {
        self.inner.closed.store(true, Ordering::Release);
        self.inner.cond.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.inner.closed.load(Ordering::Acquire)
    }
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool executing boxed jobs from a shared channel.
pub struct ThreadPool {
    jobs: Channel<Job>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(n_workers: usize, name: &str) -> Self {
        let jobs: Channel<Job> = Channel::new();
        let workers = (0..n_workers.max(1))
            .map(|i| {
                let rx = jobs.clone();
                std::thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || {
                        while let Some(job) = rx.recv() {
                            job();
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self { jobs, workers }
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.jobs.send(Box::new(f));
    }

    /// Run `jobs` on the pool and block until every one has finished.
    ///
    /// Unlike `execute`, jobs may borrow from the caller's stack
    /// (non-`'static`): soundness comes from this function not
    /// returning until all jobs have run, so no borrow can dangle
    /// (the same argument scoped-thread APIs make). A panicking job is
    /// caught on the worker (keeping the pool alive) and re-raised
    /// here after the batch completes.
    ///
    /// Must not be called from a pool worker itself: with every worker
    /// blocked in `scoped` there would be nobody left to run the jobs.
    pub fn scoped<'a>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'a>>) {
        struct Latch {
            remaining: Mutex<usize>,
            done: Condvar,
            panicked: AtomicBool,
        }
        if jobs.is_empty() {
            return;
        }
        let latch = Arc::new(Latch {
            remaining: Mutex::new(jobs.len()),
            done: Condvar::new(),
            panicked: AtomicBool::new(false),
        });
        for job in jobs {
            // SAFETY: the wait loop below blocks until this job has
            // finished executing (the latch decrement is the last thing
            // the wrapper does), so everything the job borrows outlives
            // its execution even though the pool requires 'static.
            let job = unsafe {
                std::mem::transmute::<
                    Box<dyn FnOnce() + Send + 'a>,
                    Box<dyn FnOnce() + Send + 'static>,
                >(job)
            };
            let l = Arc::clone(&latch);
            let wrapper: Job = Box::new(move || {
                if std::panic::catch_unwind(std::panic::AssertUnwindSafe(job)).is_err() {
                    l.panicked.store(true, Ordering::Release);
                }
                let mut n = l.remaining.lock().unwrap();
                *n -= 1;
                if *n == 0 {
                    l.done.notify_all();
                }
            });
            if let Some(wrapper) = self.jobs.send_or_return(wrapper) {
                // Pool shutting down: run inline so the latch still
                // reaches zero and borrows still can't dangle.
                wrapper();
            }
        }
        let mut n = latch.remaining.lock().unwrap();
        while *n > 0 {
            n = latch.done.wait(n).unwrap();
        }
        drop(n);
        if latch.panicked.load(Ordering::Acquire) {
            panic!("scoped pool job panicked");
        }
    }

    pub fn pending(&self) -> usize {
        self.jobs.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.jobs.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn channel_fifo() {
        let ch = Channel::new();
        ch.send(1);
        ch.send(2);
        assert_eq!(ch.recv(), Some(1));
        assert_eq!(ch.recv(), Some(2));
        assert_eq!(ch.try_recv(), None);
    }

    #[test]
    fn channel_close_drains_then_none() {
        let ch = Channel::new();
        ch.send(1);
        ch.close();
        assert!(!ch.send(2));
        assert_eq!(ch.recv(), Some(1));
        assert_eq!(ch.recv(), None);
    }

    #[test]
    fn send_or_return_hands_back_after_close() {
        let ch = Channel::new();
        assert_eq!(ch.send_or_return(1), None);
        ch.close();
        assert_eq!(ch.send_or_return(2), Some(2));
        assert_eq!(ch.recv(), Some(1));
        assert_eq!(ch.recv(), None);
    }

    #[test]
    fn channel_cross_thread() {
        let ch = Channel::new();
        let tx = ch.clone();
        let h = std::thread::spawn(move || {
            for i in 0..100 {
                tx.send(i);
            }
            tx.close();
        });
        let mut got = Vec::new();
        while let Some(x) = ch.recv() {
            got.push(x);
        }
        h.join().unwrap();
        assert_eq!(got, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn recv_timeout_expires() {
        let ch: Channel<u32> = Channel::new();
        let t = std::time::Instant::now();
        assert_eq!(ch.recv_timeout(std::time::Duration::from_millis(30)), None);
        assert!(t.elapsed().as_millis() >= 25);
    }

    #[test]
    fn scoped_jobs_borrow_caller_data() {
        // Jobs write disjoint chunks of a stack-local buffer — the
        // pattern the engine's sharded staging uses.
        let pool = ThreadPool::new(3, "scoped");
        let mut buf = vec![0usize; 64];
        let jobs: Vec<Box<dyn FnOnce() + Send>> = buf
            .chunks_mut(16)
            .enumerate()
            .map(|(i, chunk)| {
                Box::new(move || {
                    for (j, x) in chunk.iter_mut().enumerate() {
                        *x = i * 100 + j;
                    }
                }) as Box<dyn FnOnce() + Send>
            })
            .collect();
        pool.scoped(jobs);
        for (i, &x) in buf.iter().enumerate() {
            assert_eq!(x, (i / 16) * 100 + i % 16);
        }
    }

    #[test]
    fn scoped_blocks_until_all_jobs_finish() {
        let pool = ThreadPool::new(2, "scoped");
        let counter = Arc::new(AtomicUsize::new(0));
        let jobs: Vec<Box<dyn FnOnce() + Send>> = (0..20)
            .map(|_| {
                let c = counter.clone();
                Box::new(move || {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    c.fetch_add(1, Ordering::SeqCst);
                }) as Box<dyn FnOnce() + Send>
            })
            .collect();
        pool.scoped(jobs);
        assert_eq!(counter.load(Ordering::SeqCst), 20, "scoped returned early");
    }

    #[test]
    fn scoped_propagates_job_panic_and_pool_survives() {
        let pool = ThreadPool::new(2, "scoped");
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.scoped(vec![
                Box::new(|| {}) as Box<dyn FnOnce() + Send>,
                Box::new(|| panic!("boom")) as Box<dyn FnOnce() + Send>,
            ]);
        }));
        assert!(r.is_err(), "panic must surface to the scoped caller");
        // The pool is still usable afterwards.
        let counter = Arc::new(AtomicUsize::new(0));
        let c = counter.clone();
        pool.scoped(vec![Box::new(move || {
            c.fetch_add(1, Ordering::SeqCst);
        }) as Box<dyn FnOnce() + Send>]);
        assert_eq!(counter.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn scoped_empty_is_noop() {
        let pool = ThreadPool::new(1, "scoped");
        pool.scoped(Vec::new());
    }

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(2, "test");
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..50 {
            let c = counter.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // joins workers
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }
}
