//! Minimal JSON (serde is unavailable offline).
//!
//! Covers everything the repo needs: parsing manifests/configs/requests
//! and serializing API responses/reports. Numbers are f64; object key
//! order is preserved (Vec-backed) so emitted reports are stable.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Convenience: `obj.path("a.b.c")`.
    pub fn path(&self, dotted: &str) -> Option<&Json> {
        let mut cur = self;
        for part in dotted.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    // -- builders ----------------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn with(mut self, key: &str, val: impl Into<Json>) -> Json {
        if let Json::Obj(kv) = &mut self {
            kv.push((key.to_string(), val.into()));
        }
        self
    }

    // -- parse -------------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0, depth: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- serialize ---------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    // JSON has no NaN/Infinity literal; emitting one
                    // would produce unparsable output. Null is the
                    // conventional lossy stand-in.
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(kv) => {
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<Vec<Json>> for Json {
    fn from(a: Vec<Json>) -> Json {
        Json::Arr(a)
    }
}
impl From<BTreeMap<String, Json>> for Json {
    fn from(m: BTreeMap<String, Json>) -> Json {
        Json::Obj(m.into_iter().collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

/// Containers deeper than this are rejected: the parser is recursive,
/// so an adversarial request body of `[[[[…` would otherwise overflow
/// the stack (the HTTP layer feeds untrusted bodies straight in).
const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.pos < self.b.len()
            && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{}'", word)))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.pos += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a UTF-8 run verbatim.
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn enter(&mut self) -> Result<(), JsonError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        Ok(())
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        self.enter()?;
        let mut kv = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Obj(kv));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            kv.push((k, v));
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(kv));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        self.enter()?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.path("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].path("b").unwrap().as_str(),
            Some("x")
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"decode_b1_s128","B":1,"S":128,"ok":true,"xs":[1,2.5,"s"]}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""éx""#).unwrap();
        assert_eq!(j.as_str(), Some("éx"));
    }

    #[test]
    fn builder() {
        let j = Json::obj().with("a", 1usize).with("b", "x");
        assert_eq!(j.to_string(), r#"{"a":1,"b":"x"}"#);
    }

    #[test]
    fn errors_carry_position() {
        let e = Json::parse("{\"a\": }").unwrap_err();
        assert!(e.pos > 0);
    }

    #[test]
    fn escaped_unicode_edge_cases() {
        // \uXXXX escapes decode to the same text as literal UTF-8,
        // in values and in object keys.
        assert_eq!(Json::parse(r#""\u00e9x\u0041""#).unwrap().as_str(), Some("éxA"));
        assert_eq!(Json::parse(r#""\u4e2d\u6587""#).unwrap().as_str(), Some("中文"));
        assert_eq!(Json::parse(r#""中文""#).unwrap().as_str(), Some("中文"));
        let j = Json::parse(r#"{"k\u00e9y": 1}"#).unwrap();
        assert_eq!(j.get("kéy").and_then(Json::as_usize), Some(1));
        // A lone surrogate is not a scalar value: replaced, not crashed.
        assert_eq!(Json::parse(r#""\ud800""#).unwrap().as_str(), Some("\u{fffd}"));
        // Truncated escapes are errors, not panics.
        assert!(Json::parse(r#""\u12"#).is_err());
        assert!(Json::parse(r#""\u12G4""#).is_err());
    }

    #[test]
    fn nested_objects_in_arrays() {
        let j = Json::parse(
            r#"[{"a":[{"b":[1,2]},{"c":{"d":null}}]},[],[[{"e":"f"}]]]"#,
        )
        .unwrap();
        let top = j.as_arr().unwrap();
        assert_eq!(top.len(), 3);
        let a = top[0].get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[0].path("b").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(a[1].path("c.d").unwrap(), &Json::Null);
        assert!(top[1].as_arr().unwrap().is_empty());
        assert_eq!(
            top[2].as_arr().unwrap()[0].as_arr().unwrap()[0].path("e").unwrap().as_str(),
            Some("f")
        );
        // Round-trips through the serializer.
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn trailing_garbage_rejected() {
        for src in [
            "{\"a\":1}garbage",
            "{\"a\":1} {}",
            "[1,2]]",
            "123abc",
            "null null",
            "\"s\"x",
        ] {
            assert!(Json::parse(src).is_err(), "accepted: {src}");
        }
        // Trailing whitespace is fine.
        assert!(Json::parse("{\"a\":1}  \n").is_ok());
    }

    #[test]
    fn deep_nesting_bounded() {
        // Within bounds: parses and round-trips.
        let ok = format!("{}1{}", "[".repeat(100), "]".repeat(100));
        assert!(Json::parse(&ok).is_ok());
        // Past the bound: a structured error (not a stack overflow),
        // for arrays, objects, and mixes.
        let deep_arr = format!("{}1{}", "[".repeat(4096), "]".repeat(4096));
        assert!(Json::parse(&deep_arr).is_err());
        let deep_obj =
            format!("{}1{}", "{\"k\":".repeat(4096), "}".repeat(4096));
        assert!(Json::parse(&deep_obj).is_err());
        let mixed = format!("{}1{}", "[{\"k\":".repeat(2048), "}]".repeat(2048));
        assert!(Json::parse(&mixed).is_err());
        // Depth is tracked, not just counted: siblings don't accumulate.
        let wide = format!("[{}1]", "[1],".repeat(1000));
        assert!(Json::parse(&wide).is_ok());
    }

    #[test]
    fn control_characters_roundtrip() {
        // Every C0 control character escapes on write and parses back
        // to the identical string (torn report files aside, this is
        // what keeps journal/report text safe to re-ingest).
        let src: String = (0u32..0x20).map(|c| char::from_u32(c).unwrap()).collect();
        let j = Json::Str(src.clone());
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.as_str(), Some(src.as_str()));
        // Spot-check the named escapes take their short forms.
        assert_eq!(Json::Str("\n\t\r".into()).to_string(), r#""\n\t\r""#);
        assert_eq!(Json::Str("\u{1}".into()).to_string(), "\"\\u0001\"");
    }

    #[test]
    fn long_strings_roundtrip() {
        // 1 MiB of mixed ASCII/multibyte text, with embedded quotes
        // and backslashes every 1000 chars.
        let mut src = String::with_capacity(1 << 20);
        let mut i = 0usize;
        while src.len() < (1 << 20) {
            src.push_str("abcé中");
            if i % 1000 == 0 {
                src.push('"');
                src.push('\\');
            }
            i += 1;
        }
        let j = Json::Str(src.clone());
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.as_str(), Some(src.as_str()));
    }

    #[test]
    fn non_finite_floats_serialize_as_null() {
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let j = Json::obj().with("x", bad);
            assert_eq!(j.to_string(), r#"{"x":null}"#);
            // The output stays parsable (a bare NaN literal would not).
            let back = Json::parse(&j.to_string()).unwrap();
            assert_eq!(back.get("x"), Some(&Json::Null));
        }
        // Finite values are untouched.
        assert_eq!(Json::obj().with("x", 1.5f64).to_string(), r#"{"x":1.5}"#);
    }

    #[test]
    fn real_manifest_snippet() {
        let src = r#"{"config":{"name":"sm","d_model":128},"tensors":[{"name":"layers.0.wq","shape":[128,128]}]}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.path("config.d_model").unwrap().as_usize(), Some(128));
        let t = &j.get("tensors").unwrap().as_arr().unwrap()[0];
        assert_eq!(t.get("shape").unwrap().as_arr().unwrap()[1].as_usize(), Some(128));
    }
}
