//! Measurement substrate (criterion is unavailable offline).
//!
//! `Series` accumulates raw samples and reports mean/stddev/percentiles;
//! `Timer` wraps wallclock sections; `bench_loop` is the
//! warmup-then-measure harness the `cargo bench` targets use.

use std::time::{Duration, Instant};

/// A sample series with order-preserving percentile queries.
#[derive(Debug, Clone, Default)]
pub struct Series {
    samples: Vec<f64>,
}

impl Series {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.samples.push(x);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn stddev(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var = self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (self.samples.len() - 1) as f64;
        var.sqrt()
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Percentile in [0, 100] by nearest-rank on a sorted copy.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut v = self.samples.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        v[rank.min(v.len() - 1)]
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    pub fn sum(&self) -> f64 {
        self.samples.iter().sum()
    }

    pub fn samples(&self) -> &[f64] {
        &self.samples
    }
}

/// Wallclock section timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }

    pub fn elapsed_us(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e6
    }
}

/// Benchmark result for one named case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub per_iter: Series,
}

impl BenchResult {
    pub fn report_line(&self) -> String {
        format!(
            "{:<44} {:>10.1} us/iter (p50 {:>9.1}, p99 {:>9.1}, n={})",
            self.name,
            self.per_iter.mean(),
            self.per_iter.p50(),
            self.per_iter.p99(),
            self.iters,
        )
    }
}

/// Warmup-then-measure loop: runs `f` for `warmup` iterations, then
/// measures per-iteration wallclock (in microseconds) until either
/// `max_iters` iterations or `max_secs` seconds of measurement.
pub fn bench_loop<F: FnMut()>(
    name: &str,
    warmup: usize,
    max_iters: usize,
    max_secs: f64,
    mut f: F,
) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut per_iter = Series::new();
    let start = Instant::now();
    let mut iters = 0;
    while iters < max_iters && start.elapsed().as_secs_f64() < max_secs {
        let t = Instant::now();
        f();
        per_iter.push(t.elapsed().as_secs_f64() * 1e6);
        iters += 1;
    }
    BenchResult { name: name.to_string(), iters, per_iter }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_basic() {
        let mut s = Series::new();
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.push(x);
        }
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.p50(), 3.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert!((s.stddev() - 1.5811).abs() < 1e-3);
    }

    #[test]
    fn percentile_edges() {
        let mut s = Series::new();
        for i in 0..100 {
            s.push(i as f64);
        }
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(100.0), 99.0);
        assert_eq!(s.p99(), 98.0);
    }

    #[test]
    fn empty_series_nan() {
        let s = Series::new();
        assert!(s.mean().is_nan());
        assert!(s.percentile(50.0).is_nan());
    }

    #[test]
    fn bench_loop_runs() {
        let mut count = 0;
        let r = bench_loop("t", 2, 10, 1.0, || count += 1);
        assert_eq!(r.iters, 10);
        assert_eq!(count, 12);
        assert_eq!(r.per_iter.len(), 10);
    }
}
