//! Crash-safe filesystem helpers.
//!
//! Report artifacts and checkpoints must never be observable
//! half-written: a crash between `create` and the final `write` would
//! otherwise leave truncated JSON/CSV that downstream tooling parses
//! as corrupt (or worse, as valid-but-wrong). `write_atomic` stages
//! the bytes in a hidden temp file in the same directory, fsyncs, then
//! renames over the target — readers see either the old file or the
//! complete new one, never a prefix.

use std::fs::{self, File};
use std::io::{self, Write};
use std::path::Path;

fn stage_and_rename(tmp: &Path, path: &Path, bytes: &[u8]) -> io::Result<()> {
    let mut f = File::create(tmp)?;
    f.write_all(bytes)?;
    f.sync_data()?;
    fs::rename(tmp, path)?;
    Ok(())
}

/// Write `bytes` to `path` atomically (write temp + fsync + rename).
/// The temp file lives in the target's directory so the rename never
/// crosses a filesystem boundary; it is cleaned up on failure. The
/// directory entry is fsynced best-effort so the rename itself is
/// durable, not just the data.
pub fn write_atomic(path: impl AsRef<Path>, bytes: &[u8]) -> io::Result<()> {
    let path = path.as_ref();
    let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("out");
    let tmp = path.with_file_name(format!(".{name}.{}.tmp", std::process::id()));
    if let Err(e) = stage_and_rename(&tmp, path, bytes) {
        let _ = fs::remove_file(&tmp);
        return Err(e);
    }
    if let Some(dir) = path.parent().filter(|p| !p.as_os_str().is_empty()) {
        if let Ok(d) = File::open(dir) {
            let _ = d.sync_all();
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("radar-fsio-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn writes_fresh_file() {
        let d = tmp_dir("fresh");
        let target = d.join("report.json");
        write_atomic(&target, b"{\"ok\":true}").unwrap();
        assert_eq!(fs::read(&target).unwrap(), b"{\"ok\":true}");
        let _ = fs::remove_dir_all(&d);
    }

    #[test]
    fn replaces_existing_file_completely() {
        let d = tmp_dir("replace");
        let target = d.join("report.json");
        fs::write(&target, b"old contents that are much longer than the new ones").unwrap();
        write_atomic(&target, b"new").unwrap();
        assert_eq!(fs::read(&target).unwrap(), b"new", "no stale tail from the old file");
        let _ = fs::remove_dir_all(&d);
    }

    #[test]
    fn leaves_no_temp_files_behind() {
        let d = tmp_dir("tmpclean");
        write_atomic(d.join("a.json"), b"x").unwrap();
        let leftovers: Vec<_> = fs::read_dir(&d)
            .unwrap()
            .flatten()
            .filter(|e| e.file_name().to_string_lossy().ends_with(".tmp"))
            .collect();
        assert!(leftovers.is_empty(), "temp file survived the rename");
        let _ = fs::remove_dir_all(&d);
    }

    #[test]
    fn failure_on_missing_dir_cleans_up() {
        let d = tmp_dir("nodir");
        let target = d.join("missing").join("report.json");
        assert!(write_atomic(&target, b"x").is_err());
        let _ = fs::remove_dir_all(&d);
    }
}
