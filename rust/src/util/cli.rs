//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional
//! arguments; typed getters with defaults; auto-generated usage text.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Self {
        let mut flags = BTreeMap::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(stripped.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    flags.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Self { flags, positional }
    }

    pub fn from_env() -> Self {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Self::parse(&argv)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).map(|v| v == "true" || v == "1").unwrap_or(default)
    }

    /// Comma-separated list of usize, e.g. `--lens 512,1024,2048`.
    pub fn usize_list_or(&self, key: &str, default: &[usize]) -> Vec<usize> {
        match self.get(key) {
            Some(v) => v.split(',').filter_map(|s| s.trim().parse().ok()).collect(),
            None => default.to_vec(),
        }
    }

    pub fn str_list_or(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.get(key) {
            Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(&s.split_whitespace().map(String::from).collect::<Vec<_>>())
    }

    #[test]
    fn flags_and_positional() {
        let a = args("serve --port 8080 --verbose --model=sm extra");
        assert_eq!(a.subcommand(), Some("serve"));
        assert_eq!(a.usize_or("port", 0), 8080);
        assert!(a.bool_or("verbose", false));
        assert_eq!(a.str_or("model", "md"), "sm");
        assert_eq!(a.positional(), &["serve", "extra"]);
    }

    #[test]
    fn defaults() {
        let a = args("run");
        assert_eq!(a.usize_or("k", 8), 8);
        assert_eq!(a.f64_or("temp", 0.7), 0.7);
        assert!(!a.bool_or("verbose", false));
    }

    #[test]
    fn lists() {
        let a = args("x --lens 128,256, 512 --names a,b");
        assert_eq!(a.usize_list_or("lens", &[]), vec![128, 256]);
        assert_eq!(a.str_list_or("names", &[]), vec!["a", "b"]);
        assert_eq!(a.usize_list_or("missing", &[7]), vec![7]);
    }

    #[test]
    fn negative_numbers_as_values() {
        let a = args("x --offset=-5");
        assert_eq!(a.f64_or("offset", 0.0), -5.0);
    }
}
