//! radar-serve CLI: serving front-end + every paper experiment
//! (DESIGN.md §6 maps subcommands to tables/figures).

use anyhow::{anyhow, Result};
use radar_serve::config::PolicyKind;
use radar_serve::engine::{GenRequest, SessionEvent};
use radar_serve::harness::{bench, flagrate, longbench, ppl, theorem2, Ctx};
use radar_serve::model::tokenizer;
use radar_serve::util::cli::Args;
use radar_serve::workload::load_corpus;
use std::io::Write;

const USAGE: &str = "radar-serve <command> [--flags]

serving:
  serve       --model sm --addr 127.0.0.1:8080 --policy radar [--seed N] [--set k=v]
  generate    --model sm --prompt '...' --max-new 64 --policy radar
              [--stream]  print tokens as they decode (session stream)
              [--seed N]  reproducible sampling

robustness (--set k=v, comma-separated):
  timeout_ms=N         per-request deadline (0 = none)
  queue_timeout_ms=N   max queue wait before 408 (0 = none)
  max_preemptions=N    KV-pressure preempt budget per request
  faults=SPEC          deterministic fault injection, e.g.
                       'panic@3:1,alloc@5,slow@2x10,nan@4:1,stall@6x50'
                       or 'seeded:42:20:4'

overload & degradation (--set k=v):
  admit_rate=R         token-bucket refill, cost units/s (0 = admission off)
  admit_burst=B        token-bucket capacity (cost units)
  shed_watermark_pct=P queue/KV high-watermark that arms priority shedding
  watchdog_ms=N        per-step stall budget; offender force-finished (0 = off)
  drain_timeout_ms=N   graceful-drain deadline on SIGTERM / POST /admin/drain
  breaker_threshold=N  anomalies per window that flip exact-attention fallback
  breaker_window=N     breaker sliding window (engine steps)
  breaker_cooldown=N   quiet steps before degraded mode exits
  requests may set \"priority\": \"high\"|\"normal\"|\"batch\" (default normal);
  health surface: GET /healthz, GET /readyz, GET /metrics, POST /admin/drain

durability (--set k=v):
  journal_dir=PATH     durable session journal + crash recovery (empty = off)
  journal_fsync_every=N  journal records per fsync batch (default 8)
  checkpoint_interval_steps=N  checkpoint + epoch rotation cadence (0 = never)
  resume: GET /v1/sessions/{id} status, GET /v1/sessions/{id}/stream SSE
          replay (honors Last-Event-ID); fault 'crash@STEP[:SEQ]' simulates
          a hard abort mid-decode for recovery drills

performance:
  bench       synthetic long-context decode staging benchmark; writes
              results/BENCH_decode.json (no artifacts needed)
              [--t0 2048] [--steps 256] [--layers 4] [--heads 4] [--dh 64]
              [--window 256] [--k 48] [--seg 16] [--sinks 4]
              [--restructure-every 64] [--workers 1] [--seed 42]

experiments (paper artifacts):
  fig2        PPL + time curves: vanilla vs streaming vs radar
  fig3        no-prompt generation curves (adds h2o)
  fig4        hyper-parameter sweeps over n and k
  fig5        ablations: radar vs exact/random/lowest selection
  fig6        H2O + SnapKV failure curves on the md model
  table1      LongBench-S (all methods x n_c)
  fig7        segment-attention flag rates + heatmap CSV
  thm2        Theorem 2 Monte-Carlo
  ppl         custom curve: --policy X --prefill N --eval-len N

common flags:
  --artifacts artifacts   --model sm|md   --out results/
";

fn main() {
    let args = Args::from_env();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &Args) -> Result<()> {
    radar_serve::util::set_log_level(if args.bool_or("quiet", false) { 0 } else { 1 });
    let cmd = args.subcommand().unwrap_or("help");
    let root = args.str_or("artifacts", "artifacts");
    let out = args.str_or("out", "results");
    match cmd {
        "serve" => serve(args, root),
        "generate" => generate(args, root),
        "bench" => bench::run(args, out),
        "fig2" => fig2(args, root, out),
        "fig3" => fig3(args, root, out),
        "fig4" => fig4(args, root, out),
        "fig5" => fig5(args, root, out),
        "fig6" => fig6(args, root, out),
        "table1" => table1(args, root, out),
        "fig7" => fig7(args, root, out),
        "thm2" => thm2(args, out),
        "ppl" => custom_ppl(args, root, out),
        "inspect-artifacts" => inspect(args, root),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

fn serving_overrides(args: &Args) -> Vec<(String, String)> {
    // --set k=v,k2=v2 plus first-class flags (--seed N).
    let mut ov: Vec<(String, String)> = args
        .get("set")
        .map(|s| {
            s.split(',')
                .filter_map(|kv| kv.split_once('='))
                .map(|(k, v)| (k.to_string(), v.to_string()))
                .collect()
        })
        .unwrap_or_default();
    if let Some(seed) = args.get("seed") {
        ov.push(("seed".to_string(), seed.to_string()));
    }
    ov
}

fn serve(args: &Args, root: &str) -> Result<()> {
    let ctx = Ctx::load(root, args.str_or("model", "sm"))?;
    let policy = PolicyKind::parse(args.str_or("policy", "radar"))?;
    let ov = serving_overrides(args);
    let ov_ref: Vec<(&str, &str)> = ov.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
    let engine = ctx.engine(policy, &ov_ref)?;
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    radar_serve::server::serve(engine, args.str_or("addr", "127.0.0.1:8080"), stop)
}

fn generate(args: &Args, root: &str) -> Result<()> {
    let ctx = Ctx::load(root, args.str_or("model", "sm"))?;
    let policy = PolicyKind::parse(args.str_or("policy", "radar"))?;
    let ov = serving_overrides(args);
    let ov_ref: Vec<(&str, &str)> = ov.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
    let mut engine = ctx.engine(policy, &ov_ref)?;
    let prompt = args.get("prompt").ok_or_else(|| anyhow!("--prompt required"))?;
    let stream = args.bool_or("stream", false);
    let req = GenRequest::new(tokenizer::encode(prompt), args.usize_or("max-new", 64));
    let handle = engine.submit(req)?;
    // Single-threaded session consumption: step the engine ourselves
    // and drain the handle between steps.
    if stream {
        print!("{prompt}");
        std::io::stdout().flush()?;
    }
    let mut generated: Vec<i32> = Vec::new();
    let mut usage = None;
    while !engine.idle() {
        engine.step()?;
        while let Some(ev) = handle.try_recv() {
            match ev {
                SessionEvent::Token { token, .. } => {
                    if stream {
                        print!("{}", tokenizer::decode(&[token]));
                        std::io::stdout().flush()?;
                    }
                    generated.push(token);
                }
                SessionEvent::Done { usage: u, .. } => usage = Some(u),
                SessionEvent::Error(e) => return Err(anyhow!("generation failed: {e}")),
            }
        }
    }
    if stream {
        println!();
    } else {
        println!("{prompt}{}", tokenizer::decode(&generated));
    }
    if let Some(u) = usage {
        eprintln!(
            "[{} tokens, prefill {:.1} ms, decode {:.1} ms, {:.1} tok/s]",
            u.completion_tokens,
            u.prefill_ms,
            u.decode_ms,
            u.completion_tokens as f64 / (u.decode_ms / 1e3).max(1e-9)
        );
    }
    if engine.metrics.counter("prefix_hits") + engine.metrics.counter("prefix_misses") > 0 {
        eprintln!("[{}]", radar_serve::harness::report::prefix_cache_summary(&engine.metrics));
    }
    let faults = engine.metrics.counter("contained_errors")
        + engine.metrics.counter("preemptions")
        + engine.metrics.counter("timeouts")
        + engine.metrics.counter("shed_requests")
        + engine.metrics.counter("watchdog_trips")
        + engine.metrics.counter("anomaly_fallbacks");
    if faults > 0 {
        eprintln!("[{}]", radar_serve::harness::report::robustness_summary(&engine.metrics));
    }
    Ok(())
}

/// Fig. 2: book + code corpora, prefill 1024, decode to eval_len.
fn fig2(args: &Args, root: &str, out: &str) -> Result<()> {
    let model = args.str_or("model", "sm");
    let ctx = Ctx::load(root, model)?;
    let prefill = args.usize_or("prefill", 1024);
    let eval_len = args.usize_or("eval-len", if model == "sm" { 3072 } else { 2048 });
    let every = args.usize_or("every", 256);
    for corpus_name in ["book_eval.bin", "code_eval.bin"] {
        let corpus = load_corpus(&ctx.paths, corpus_name)?;
        let mut curves = Vec::new();
        for p in [PolicyKind::Vanilla, PolicyKind::Streaming, PolicyKind::Radar] {
            let ov: Vec<(&str, &str)> = match p {
                PolicyKind::Streaming => vec![("window", "64"), ("budget", "192")],
                _ => vec![],
            };
            curves.push(ppl::ppl_curve(&ctx, p, &ov, &corpus, prefill, eval_len, every)?);
            radar_serve::info!("fig2 {corpus_name}: {} done", p.name());
        }
        ppl::print_curves(
            &format!("Fig 2 [{model}/{corpus_name}] prefill={prefill}"),
            &curves,
            &format!("{out}/fig2_{model}_{}.csv", corpus_name.trim_end_matches(".bin")),
        )?;
    }
    Ok(())
}

/// Fig. 3: generation without prompts (prefill ~1 token).
fn fig3(args: &Args, root: &str, out: &str) -> Result<()> {
    let model = args.str_or("model", "sm");
    let ctx = Ctx::load(root, model)?;
    let eval_len = args.usize_or("eval-len", 1536);
    let corpus = load_corpus(&ctx.paths, "book_eval.bin")?;
    let mut curves = Vec::new();
    for p in [PolicyKind::Vanilla, PolicyKind::Streaming, PolicyKind::H2O, PolicyKind::Radar] {
        let ov: Vec<(&str, &str)> = match p {
            PolicyKind::Streaming => vec![("window", "64"), ("budget", "192")],
            PolicyKind::H2O => vec![("window", "64"), ("budget", "192")],
            _ => vec![],
        };
        curves.push(ppl::ppl_curve(&ctx, p, &ov, &corpus, 1, eval_len, 128)?);
        radar_serve::info!("fig3: {} done", p.name());
    }
    ppl::print_curves(
        &format!("Fig 3 [{model}] no-prompt generation"),
        &curves,
        &format!("{out}/fig3_{model}.csv"),
    )
}

/// Fig. 4: PPL at fixed length vs n (k=8) and vs k (n=128).
fn fig4(args: &Args, root: &str, out: &str) -> Result<()> {
    let ctx = Ctx::load(root, "sm")?;
    let corpus = load_corpus(&ctx.paths, "book_eval.bin")?;
    // Stay inside the model's native context (max_train_len) so the
    // sweep measures selection quality, not RoPE extrapolation.
    let prefill = args.usize_or("prefill", 128);
    let eval_len = args.usize_or("eval-len", 512);
    let mut curves = Vec::new();
    for n in args.usize_list_or("ns", &[32, 64, 128, 256]) {
        let ns = n.to_string();
        let ov = vec![("n_feat", ns.as_str())];
        curves.push(ppl::ppl_curve(&ctx, PolicyKind::Radar, &ov, &corpus, prefill, eval_len, 512)?);
        radar_serve::info!("fig4: n={n} done");
    }
    for k in args.usize_list_or("ks", &[2, 4, 8, 16]) {
        let ks = k.to_string();
        let ov = vec![("k", ks.as_str())];
        curves.push(ppl::ppl_curve(&ctx, PolicyKind::Radar, &ov, &corpus, prefill, eval_len, 512)?);
        radar_serve::info!("fig4: k={k} done");
    }
    ppl::print_curves("Fig 4: effect of n and k", &curves, &format!("{out}/fig4.csv"))
}

/// Fig. 5: selection-strategy ablations.
fn fig5(args: &Args, root: &str, out: &str) -> Result<()> {
    let ctx = Ctx::load(root, "sm")?;
    let corpus = load_corpus(&ctx.paths, "book_eval.bin")?;
    // Native-context evaluation (see fig4 note).
    let prefill = args.usize_or("prefill", 128);
    let eval_len = args.usize_or("eval-len", 512);
    let mut curves = Vec::new();
    for p in [
        PolicyKind::Radar,
        PolicyKind::RadarLowest,
        PolicyKind::RadarRandom,
        PolicyKind::RadarExact,
    ] {
        // window=16 isolates segment selection (the shared sliding
        // window would otherwise mask the strategies' differences).
        let ov = vec![("window", "16")];
        curves.push(ppl::ppl_curve(&ctx, p, &ov, &corpus, prefill, eval_len, 256)?);
        radar_serve::info!("fig5: {} done", p.name());
    }
    ppl::print_curves("Fig 5: segment-selection ablations", &curves, &format!("{out}/fig5.csv"))
}

/// Fig. 6: H2O + SnapKV on the md model (failure shapes).
fn fig6(args: &Args, root: &str, out: &str) -> Result<()> {
    let ctx = Ctx::load(root, "md")?;
    let corpus = load_corpus(&ctx.paths, "book_eval.bin")?;
    let prefill = args.usize_or("prefill", 512);
    let eval_len = args.usize_or("eval-len", 1536);
    let mut curves = Vec::new();
    for p in [PolicyKind::Vanilla, PolicyKind::H2O, PolicyKind::SnapKV, PolicyKind::Radar] {
        let ov: Vec<(&str, &str)> = match p {
            PolicyKind::H2O | PolicyKind::SnapKV => vec![("window", "64"), ("budget", "192")],
            _ => vec![],
        };
        curves.push(ppl::ppl_curve(&ctx, p, &ov, &corpus, prefill, eval_len, 256)?);
        radar_serve::info!("fig6: {} done", p.name());
    }
    ppl::print_curves("Fig 6 [md]: H2O/SnapKV failures", &curves, &format!("{out}/fig6_md.csv"))
}

/// Table 1: LongBench-S.
fn table1(args: &Args, root: &str, out: &str) -> Result<()> {
    let model = args.str_or("model", "sm");
    let ctx = Ctx::load(root, model)?;
    let instances = args.usize_or("instances", 3);
    let methods = [
        PolicyKind::Vanilla,
        PolicyKind::Streaming,
        PolicyKind::H2O,
        PolicyKind::SnapKV,
        PolicyKind::SubGen,
        PolicyKind::Radar,
    ];
    for nc in args.usize_list_or("ncs", &[128, 256]) {
        let ctx_len = args.usize_or("ctx-len", 448);
        let rows = longbench::run_table(&ctx, ctx_len, nc, instances, &methods)?;
        longbench::print_table(
            &format!("Table 1 [{model}] n_c={nc} ctx={ctx_len} (Landmark: N/A, training-based)"),
            &rows,
            &format!("{out}/table1_{model}_nc{nc}.csv"),
        )?;
    }
    Ok(())
}

fn fig7(args: &Args, root: &str, out: &str) -> Result<()> {
    let ctx = Ctx::load(root, args.str_or("model", "sm"))?;
    let corpus = load_corpus(&ctx.paths, "book_eval.bin")?;
    let n_queries = args.usize_or("queries", 32);
    let n_feat = args.usize_or("n", 128);
    let o = flagrate::run(&ctx, &corpus, n_queries, n_feat)?;
    flagrate::print(&o, &format!("{out}/fig7_heatmap.csv"))
}

fn thm2(args: &Args, out: &str) -> Result<()> {
    let points = theorem2::run(args.usize_or("trials", 200), 7)?;
    theorem2::print(&points, &format!("{out}/thm2.csv"))
}

fn custom_ppl(args: &Args, root: &str, out: &str) -> Result<()> {
    let ctx = Ctx::load(root, args.str_or("model", "sm"))?;
    let corpus = load_corpus(&ctx.paths, args.str_or("corpus", "book_eval.bin"))?;
    let policy = PolicyKind::parse(args.str_or("policy", "radar"))?;
    let ov = serving_overrides(args);
    let ov_ref: Vec<(&str, &str)> = ov.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
    let curve = ppl::ppl_curve(
        &ctx,
        policy,
        &ov_ref,
        &corpus,
        args.usize_or("prefill", 512),
        args.usize_or("eval-len", 1536),
        args.usize_or("every", 256),
    )?;
    ppl::print_curves("custom ppl", &[curve], &format!("{out}/ppl_custom.csv"))
}

fn inspect(args: &Args, root: &str) -> Result<()> {
    let ctx = Ctx::load(root, args.str_or("model", "sm"))?;
    println!("model: {:?}", ctx.rt.config);
    println!("{} artifacts:", ctx.rt.registry.len());
    for a in ctx.rt.registry.all() {
        println!("  {:?} {} (B={} len={} n={})", a.kind, a.name, a.batch, a.len, a.n_feat);
    }
    Ok(())
}
