//! Model-adjacent helpers that live rust-side: the byte tokenizer,
//! embedding lookup + final head (cheap row-copy / small matmul done on
//! host from the weight host-copies — verified against python goldens),
//! and sampling.

use crate::config::ModelConfig;
use crate::runtime::Runtime;
use crate::util::prng::SplitMix64;

/// Byte-level tokenizer: text <-> u8 ids (vocab 256).
pub mod tokenizer {
    pub fn encode(text: &str) -> Vec<i32> {
        text.as_bytes().iter().map(|&b| b as i32).collect()
    }

    pub fn encode_bytes(bytes: &[u8]) -> Vec<i32> {
        bytes.iter().map(|&b| b as i32).collect()
    }

    pub fn decode(ids: &[i32]) -> String {
        let bytes: Vec<u8> = ids.iter().map(|&i| (i.clamp(0, 255)) as u8).collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn roundtrip_ascii() {
            let s = "hello <<k17:v83>> def fn_01(x):";
            assert_eq!(decode(&encode(s)), s);
        }

        #[test]
        fn bytes_match_python_byte_level() {
            assert_eq!(encode("Ab"), vec![65, 98]);
        }
    }
}

/// Host-side embedding lookup: x[b] = emb[token_b]. Layout [B, d].
pub fn embed(rt: &Runtime, tokens: &[i32]) -> Vec<f32> {
    let (shape, emb) = rt.weights.host_tensor("emb").expect("emb tensor");
    let d = shape[1];
    let mut x = Vec::with_capacity(tokens.len() * d);
    for &t in tokens {
        let row = (t as usize).min(shape[0] - 1) * d;
        x.extend_from_slice(&emb[row..row + d]);
    }
    x
}

/// Host-side final head: logits = rmsnorm(x, ln_f) @ emb^T. x: [B, d].
/// Returns [B, V]. Verified against `golden.npz` head vectors.
pub fn head(rt: &Runtime, cfg: &ModelConfig, x: &[f32]) -> Vec<f32> {
    let (_, ln_f) = rt.weights.host_tensor("ln_f").expect("ln_f");
    let (eshape, emb) = rt.weights.host_tensor("emb").expect("emb");
    let (v, d) = (eshape[0], eshape[1]);
    let b = x.len() / d;
    let mut logits = vec![0.0f32; b * v];
    let eps = 1e-5f32;
    let mut xn = vec![0.0f32; d];
    for bi in 0..b {
        let row = &x[bi * d..(bi + 1) * d];
        let ms: f32 = row.iter().map(|a| a * a).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + eps).sqrt();
        for i in 0..d {
            xn[i] = row[i] * inv * ln_f[i];
        }
        let out = &mut logits[bi * v..(bi + 1) * v];
        for (vi, o) in out.iter_mut().enumerate() {
            let erow = &emb[vi * d..(vi + 1) * d];
            let mut acc = 0.0f32;
            for i in 0..d {
                acc += xn[i] * erow[i];
            }
            *o = acc;
        }
    }
    let _ = cfg;
    logits
}

/// Sampling over a logits row.
pub struct Sampler {
    rng: SplitMix64,
    pub temperature: f32,
    pub greedy: bool,
}

impl Sampler {
    pub fn new(seed: u64, temperature: f32, greedy: bool) -> Self {
        Self { rng: SplitMix64::new(seed), temperature, greedy }
    }

    pub fn sample(&mut self, logits: &[f32]) -> i32 {
        if self.greedy {
            return argmax(logits) as i32;
        }
        let t = self.temperature.max(1e-3);
        let m = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let exps: Vec<f32> = logits.iter().map(|&l| ((l - m) / t).exp()).collect();
        let z: f32 = exps.iter().sum();
        let mut u = self.rng.next_f32() * z;
        for (i, &e) in exps.iter().enumerate() {
            u -= e;
            if u <= 0.0 {
                return i as i32;
            }
        }
        (exps.len() - 1) as i32
    }

    /// Fast-forward past `n` already-journaled samples so a recovered
    /// sequence's next draw matches what the uncrashed run would have
    /// produced. Greedy sampling consumes no randomness (`sample`
    /// returns the argmax without touching the RNG), so skipping is a
    /// no-op there; otherwise `sample` draws exactly one `next_f32`
    /// per token, so burn exactly `n` draws.
    pub fn skip(&mut self, n: usize) {
        if self.greedy {
            return;
        }
        for _ in 0..n {
            self.rng.next_f32();
        }
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut bi = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bv {
            bv = x;
            bi = i;
        }
    }
    bi
}

/// log-softmax probability of `target` under `logits` (PPL evaluation).
pub fn log_prob(logits: &[f32], target: usize) -> f64 {
    let m = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
    let z: f64 = logits.iter().map(|&l| ((l as f64) - m).exp()).sum();
    (logits[target] as f64) - m - z.ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 3.0, -1.0]), 1);
        assert_eq!(argmax(&[-5.0, -2.0]), 1);
    }

    #[test]
    fn greedy_sampler_is_argmax() {
        let mut s = Sampler::new(0, 1.0, true);
        assert_eq!(s.sample(&[0.0, 9.0, 1.0]), 1);
    }

    #[test]
    fn temperature_sampler_in_range_and_deterministic() {
        let mut s1 = Sampler::new(7, 0.8, false);
        let mut s2 = Sampler::new(7, 0.8, false);
        let logits = vec![0.5f32; 16];
        for _ in 0..50 {
            let a = s1.sample(&logits);
            assert_eq!(a, s2.sample(&logits));
            assert!((0..16).contains(&a));
        }
    }

    #[test]
    fn skip_fast_forwards_to_identical_stream() {
        // A fresh sampler that skips n draws continues exactly where a
        // sampler that made n real draws left off (crash recovery).
        let logits: Vec<f32> = (0..16).map(|i| (i as f32 * 0.37).sin()).collect();
        for n in [0usize, 1, 5, 17] {
            let mut live = Sampler::new(11, 0.8, false);
            let mut tail: Vec<i32> = Vec::new();
            for i in 0..n + 8 {
                let t = live.sample(&logits);
                if i >= n {
                    tail.push(t);
                }
            }
            let mut recovered = Sampler::new(11, 0.8, false);
            recovered.skip(n);
            let got: Vec<i32> = (0..8).map(|_| recovered.sample(&logits)).collect();
            assert_eq!(got, tail, "skip({n}) diverged");
        }
        // Greedy consumes no randomness: skip must not perturb it.
        let mut g = Sampler::new(3, 1.0, true);
        g.skip(100);
        assert_eq!(g.sample(&[0.0, 9.0, 1.0]), 1);
    }

    #[test]
    fn log_prob_normalized() {
        let logits = vec![1.0f32, 2.0, 0.5];
        let total: f64 = (0..3).map(|i| log_prob(&logits, i).exp()).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }
}
