//! Configuration: the model ABI (mirrors `python/compile/model.py`) and
//! serving-time knobs. Loaded from the artifact manifest plus optional
//! JSON config files / CLI overrides.

use crate::faults::FaultPlan;
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

/// Model architecture — must match the python `ModelConfig` exactly;
/// it is read from `artifacts/<model>/manifest.json`, never hardcoded.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub d_ffn: usize,
    pub n_feat: usize,
    pub max_train_len: usize,
    pub vocab: usize,
}

impl ModelConfig {
    pub fn from_json(j: &Json) -> Result<Self> {
        let g = |k: &str| -> Result<usize> {
            j.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("manifest config missing '{k}'"))
        };
        Ok(Self {
            name: j
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("manifest config missing 'name'"))?
                .to_string(),
            d_model: g("d_model")?,
            n_layers: g("n_layers")?,
            n_heads: g("n_heads")?,
            d_head: g("d_head")?,
            d_ffn: g("d_ffn")?,
            n_feat: g("n_feat")?,
            max_train_len: g("max_train_len")?,
            vocab: g("vocab")?,
        })
    }

    pub fn d_attn(&self) -> usize {
        self.n_heads * self.d_head
    }

    /// (layer, head) pair count — selection policies run per pair.
    pub fn n_lh(&self) -> usize {
        self.n_layers * self.n_heads
    }
}

/// Which token-selection method serves a request (DESIGN.md §5 policy/).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PolicyKind {
    /// Full attention over the entire cache (the quadratic baseline).
    Vanilla,
    /// StreamingLLM: sinks + sliding window; middle tokens evicted.
    Streaming,
    /// H2O: sinks + window + accumulated-attention heavy hitters.
    H2O,
    /// SnapKV: prompt tokens selected once at prefill end, then frozen.
    SnapKV,
    /// SubGen-style: online k-means centroids over keys + window.
    SubGen,
    /// The paper: top-k segments by random-feature scores + window.
    Radar,
    /// Ablations (Fig. 5).
    RadarExact,
    RadarRandom,
    RadarLowest,
}

impl PolicyKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "vanilla" | "full" => Self::Vanilla,
            "streaming" | "streamingllm" => Self::Streaming,
            "h2o" => Self::H2O,
            "snapkv" => Self::SnapKV,
            "subgen" => Self::SubGen,
            "radar" => Self::Radar,
            "radar-exact" | "exact" => Self::RadarExact,
            "radar-random" | "random" => Self::RadarRandom,
            "radar-lowest" | "lowest" => Self::RadarLowest,
            other => return Err(anyhow!("unknown policy '{other}'")),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Vanilla => "vanilla",
            Self::Streaming => "streaming",
            Self::H2O => "h2o",
            Self::SnapKV => "snapkv",
            Self::SubGen => "subgen",
            Self::Radar => "radar",
            Self::RadarExact => "radar-exact",
            Self::RadarRandom => "radar-random",
            Self::RadarLowest => "radar-lowest",
        }
    }

    pub fn all() -> &'static [PolicyKind] {
        &[
            Self::Vanilla,
            Self::Streaming,
            Self::H2O,
            Self::SnapKV,
            Self::SubGen,
            Self::Radar,
            Self::RadarExact,
            Self::RadarRandom,
            Self::RadarLowest,
        ]
    }
}

/// Serving-time knobs (paper defaults rescaled per DESIGN.md §7).
#[derive(Debug, Clone)]
pub struct ServingConfig {
    pub policy: PolicyKind,
    /// Radar: number of top segments (paper: 64 @ 16-32K ctx; ours: 8).
    pub radar_k: usize,
    /// Random-feature dimension n; must match an `omega_n{N}` artifact.
    pub n_feat: usize,
    /// Always-kept sink tokens (StreamingLLM-style; Radar keeps them too).
    pub sinks: usize,
    /// Token budget for eviction-based policies (the paper's 32 + n_c).
    pub budget: usize,
    /// Sliding-window length for streaming/h2o/snapkv.
    pub window: usize,
    /// Max concurrent decode batch (must match a compiled B bucket).
    pub max_batch: usize,
    /// Bounded admission queue: `Engine::submit` rejects (HTTP 429)
    /// once this many requests are waiting for a decode slot.
    pub max_pending: usize,
    /// Cap on tokens per sequence (cache capacity).
    pub max_seq_len: usize,
    /// Shared-prefix KV reuse (the radix prefix index). Per-request
    /// opt-out via the API's `cache: off`.
    pub prefix_cache: bool,
    /// Byte budget for the prefix index (KV blocks + frozen Radar
    /// summaries); LRU leaf eviction keeps the tree under it.
    pub prefix_cache_mb: usize,
    /// Sampling.
    pub temperature: f32,
    pub greedy: bool,
    pub seed: u64,
    /// KV-pressure preemption: how many times one request may be
    /// preempted-and-requeued before it fails with a capacity error.
    pub max_preemptions: u32,
    /// Default per-request wall-clock deadline, submit -> last token
    /// (0 = no deadline; requests may override via `timeout_ms`).
    pub timeout_ms: u64,
    /// Deadline on queue wait alone: a request still pending after this
    /// long times out without ever being admitted (0 = no limit).
    pub queue_timeout_ms: u64,
    /// HTTP keep-alive: idle read timeout between requests on one
    /// connection (0 = wait forever).
    pub keep_alive_idle_ms: u64,
    /// Server shutdown-race backstop: how long a connection thread
    /// waits for the engine loop to acknowledge a submit
    /// (0 = wait forever).
    pub reply_timeout_ms: u64,
    /// Admission token-bucket refill rate in estimated tokens/second
    /// (cost = uncached prefill + max_new_tokens). 0 disables the gate.
    pub admit_rate: f64,
    /// Admission token-bucket capacity (burst) in estimated tokens.
    pub admit_burst: f64,
    /// High-watermark, in percent, of both the pending queue (vs
    /// `max_pending`) and the KV pool (vs total blocks). Crossing it
    /// starts shedding lowest-priority queued work and flips `/readyz`.
    pub shed_watermark_pct: u8,
    /// Watchdog: a sequence whose step body runs longer than this with
    /// no progress is force-finished through the containment path
    /// (0 = watchdog off).
    pub watchdog_ms: u64,
    /// Graceful drain: how long in-flight sequences may keep running
    /// after SIGTERM / `/admin/drain` before `fail_all` (0 = forever).
    pub drain_timeout_ms: u64,
    /// Circuit breaker: this many anomalies or contained errors within
    /// `breaker_window` engine steps flips the engine into
    /// exact-attention degraded mode (0 = breaker off).
    pub breaker_threshold: u32,
    /// Circuit breaker: sliding event window, in engine steps.
    pub breaker_window: u64,
    /// Circuit breaker: degraded-mode cool-down, in engine steps.
    pub breaker_cooldown: u64,
    /// Incremental K/V staging: diff each step's selection against the
    /// per-sequence staged arena and gather only changed rows. `false`
    /// forces a full re-gather every step (the baseline the bench and
    /// byte-identity tests compare against).
    pub stage_delta: bool,
    /// Worker threads for sharded staging and plane-parallel segment
    /// scoring; 1 = serial on the engine thread (no pool spawned).
    pub stage_workers: usize,
    /// Directory for the durable session journal + checkpoints; empty
    /// disables journaling (and with it crash recovery and resume).
    pub journal_dir: String,
    /// Journal frames appended between `fsync`s. 1 = every record is
    /// durable before the next step (safest, slowest); larger batches
    /// bound what a hard abort can lose — and deterministic sampling
    /// regenerates lost-tail tokens identically on recovery anyway.
    pub journal_fsync_every: usize,
    /// Engine steps between journal checkpoints (checkpoints bound
    /// replay and rotate the journal); 0 = never checkpoint.
    pub checkpoint_interval_steps: u64,
    /// Deterministic fault injection (tests / chaos harness only).
    pub faults: Option<FaultPlan>,
}

impl Default for ServingConfig {
    fn default() -> Self {
        Self {
            policy: PolicyKind::Radar,
            radar_k: 8,
            n_feat: 128,
            sinks: 4,
            budget: 256,
            window: 64,
            max_batch: 4,
            max_pending: 32,
            max_seq_len: 4096,
            prefix_cache: true,
            prefix_cache_mb: 64,
            temperature: 1.0,
            greedy: true,
            seed: 0,
            max_preemptions: 3,
            timeout_ms: 0,
            queue_timeout_ms: 0,
            keep_alive_idle_ms: 30_000,
            reply_timeout_ms: 30_000,
            admit_rate: 0.0,
            admit_burst: 8192.0,
            shed_watermark_pct: 80,
            watchdog_ms: 0,
            drain_timeout_ms: 5_000,
            breaker_threshold: 8,
            breaker_window: 32,
            breaker_cooldown: 64,
            stage_delta: true,
            stage_workers: 1,
            journal_dir: String::new(),
            journal_fsync_every: 8,
            checkpoint_interval_steps: 256,
            faults: None,
        }
    }
}

impl ServingConfig {
    /// Apply `key=value` overrides (CLI `--set k=v,k2=v2`).
    pub fn apply_override(&mut self, key: &str, val: &str) -> Result<()> {
        match key {
            "policy" => self.policy = PolicyKind::parse(val)?,
            "radar_k" | "k" => self.radar_k = val.parse()?,
            "n_feat" | "n" => self.n_feat = val.parse()?,
            "sinks" => self.sinks = val.parse()?,
            "budget" => self.budget = val.parse()?,
            "window" => self.window = val.parse()?,
            "max_batch" => self.max_batch = val.parse()?,
            "max_pending" => self.max_pending = val.parse()?,
            "max_seq_len" => self.max_seq_len = val.parse()?,
            "prefix_cache" => {
                self.prefix_cache = match val {
                    "on" | "true" | "1" => true,
                    "off" | "false" | "0" => false,
                    other => return Err(anyhow!("prefix_cache: expected on/off, got '{other}'")),
                }
            }
            "prefix_cache_mb" => self.prefix_cache_mb = val.parse()?,
            "temperature" => self.temperature = val.parse()?,
            "greedy" => self.greedy = val == "true" || val == "1",
            "seed" => self.seed = val.parse()?,
            "max_preemptions" => self.max_preemptions = val.parse()?,
            "timeout_ms" => self.timeout_ms = val.parse()?,
            "queue_timeout_ms" => self.queue_timeout_ms = val.parse()?,
            "keep_alive_idle_ms" => self.keep_alive_idle_ms = val.parse()?,
            "reply_timeout_ms" => self.reply_timeout_ms = val.parse()?,
            "admit_rate" => self.admit_rate = val.parse()?,
            "admit_burst" => self.admit_burst = val.parse()?,
            "shed_watermark_pct" => {
                let pct: u8 = val.parse()?;
                if pct == 0 || pct > 100 {
                    return Err(anyhow!("shed_watermark_pct: expected 1..=100, got '{val}'"));
                }
                self.shed_watermark_pct = pct;
            }
            "watchdog_ms" => self.watchdog_ms = val.parse()?,
            "drain_timeout_ms" => self.drain_timeout_ms = val.parse()?,
            "breaker_threshold" => self.breaker_threshold = val.parse()?,
            "breaker_window" => self.breaker_window = val.parse()?,
            "breaker_cooldown" => self.breaker_cooldown = val.parse()?,
            "stage_delta" => {
                self.stage_delta = match val {
                    "on" | "true" | "1" => true,
                    "off" | "false" | "0" => false,
                    other => return Err(anyhow!("stage_delta: expected on/off, got '{other}'")),
                }
            }
            "stage_workers" => {
                let n: usize = val.parse()?;
                if n == 0 {
                    return Err(anyhow!("stage_workers: expected >= 1, got '{val}'"));
                }
                self.stage_workers = n;
            }
            "journal_dir" => self.journal_dir = val.to_string(),
            "journal_fsync_every" => {
                let n: usize = val.parse()?;
                if n == 0 {
                    return Err(anyhow!("journal_fsync_every: expected >= 1, got '{val}'"));
                }
                self.journal_fsync_every = n;
            }
            "checkpoint_interval_steps" => self.checkpoint_interval_steps = val.parse()?,
            "faults" => self.faults = Some(FaultPlan::parse(val)?),
            other => return Err(anyhow!("unknown serving option '{other}'")),
        }
        Ok(())
    }
}

/// Root paths for an artifact set.
#[derive(Debug, Clone)]
pub struct ArtifactPaths {
    pub root: PathBuf,
    pub model: String,
}

impl ArtifactPaths {
    pub fn new(root: impl AsRef<Path>, model: &str) -> Self {
        Self { root: root.as_ref().to_path_buf(), model: model.to_string() }
    }

    pub fn model_dir(&self) -> PathBuf {
        self.root.join(&self.model)
    }

    pub fn manifest(&self) -> PathBuf {
        self.model_dir().join("manifest.json")
    }

    pub fn weights(&self) -> PathBuf {
        self.model_dir().join("weights.npz")
    }

    pub fn omega(&self, n: usize) -> PathBuf {
        self.model_dir().join(format!("omega_n{n}.npz"))
    }

    pub fn golden(&self) -> PathBuf {
        self.model_dir().join("golden.npz")
    }

    pub fn hlo(&self, name: &str) -> PathBuf {
        self.model_dir().join(format!("{name}.hlo.txt"))
    }

    pub fn corpus(&self, name: &str) -> PathBuf {
        self.root.join("corpus").join(name)
    }

    pub fn load_manifest(&self) -> Result<Json> {
        let text = std::fs::read_to_string(self.manifest())
            .with_context(|| format!("reading {:?} (run `make artifacts`)", self.manifest()))?;
        Ok(Json::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_config_from_json() {
        let j = Json::parse(
            r#"{"name":"sm","d_model":128,"n_layers":4,"n_heads":2,
                "d_head":64,"d_ffn":512,"n_feat":128,"max_train_len":512,
                "rope_theta":10000.0,"norm_eps":1e-5,"vocab":256}"#,
        )
        .unwrap();
        let c = ModelConfig::from_json(&j).unwrap();
        assert_eq!(c.d_attn(), 128);
        assert_eq!(c.n_lh(), 8);
    }

    #[test]
    fn model_config_missing_field_errors() {
        let j = Json::parse(r#"{"name":"sm","d_model":128}"#).unwrap();
        assert!(ModelConfig::from_json(&j).is_err());
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in PolicyKind::all() {
            assert_eq!(PolicyKind::parse(p.name()).unwrap(), *p);
        }
        assert!(PolicyKind::parse("nope").is_err());
    }

    #[test]
    fn serving_overrides() {
        let mut s = ServingConfig::default();
        s.apply_override("policy", "h2o").unwrap();
        s.apply_override("k", "16").unwrap();
        s.apply_override("budget", "512").unwrap();
        s.apply_override("max_pending", "8").unwrap();
        assert_eq!(s.policy, PolicyKind::H2O);
        assert_eq!(s.radar_k, 16);
        assert_eq!(s.budget, 512);
        assert_eq!(s.max_pending, 8);
        assert!(s.apply_override("bogus", "1").is_err());
    }

    #[test]
    fn prefix_cache_overrides() {
        let mut s = ServingConfig::default();
        assert!(s.prefix_cache, "reuse is on by default");
        assert_eq!(s.prefix_cache_mb, 64);
        s.apply_override("prefix_cache", "off").unwrap();
        assert!(!s.prefix_cache);
        s.apply_override("prefix_cache", "1").unwrap();
        assert!(s.prefix_cache);
        s.apply_override("prefix_cache", "false").unwrap();
        assert!(!s.prefix_cache);
        assert!(s.apply_override("prefix_cache", "maybe").is_err());
        s.apply_override("prefix_cache_mb", "128").unwrap();
        assert_eq!(s.prefix_cache_mb, 128);
        assert!(s.apply_override("prefix_cache_mb", "lots").is_err());
    }

    #[test]
    fn robustness_overrides() {
        let mut s = ServingConfig::default();
        assert_eq!(s.max_preemptions, 3);
        assert_eq!(s.timeout_ms, 0, "deadlines are off by default");
        assert_eq!(s.queue_timeout_ms, 0);
        assert_eq!(s.keep_alive_idle_ms, 30_000);
        assert_eq!(s.reply_timeout_ms, 30_000);
        assert!(s.faults.is_none());
        s.apply_override("max_preemptions", "1").unwrap();
        s.apply_override("timeout_ms", "5000").unwrap();
        s.apply_override("queue_timeout_ms", "250").unwrap();
        s.apply_override("keep_alive_idle_ms", "0").unwrap();
        s.apply_override("reply_timeout_ms", "100").unwrap();
        s.apply_override("faults", "alloc@3:1,slow@5x10").unwrap();
        assert_eq!(s.max_preemptions, 1);
        assert_eq!(s.timeout_ms, 5000);
        assert_eq!(s.queue_timeout_ms, 250);
        assert_eq!(s.keep_alive_idle_ms, 0);
        assert_eq!(s.reply_timeout_ms, 100);
        assert_eq!(s.faults.as_ref().map(|f| f.events.len()), Some(2));
        assert!(s.apply_override("faults", "bogus@1").is_err());
    }

    #[test]
    fn overload_overrides() {
        let mut s = ServingConfig::default();
        assert_eq!(s.admit_rate, 0.0, "admission gate is off by default");
        assert_eq!(s.shed_watermark_pct, 80);
        assert_eq!(s.watchdog_ms, 0, "watchdog is off by default");
        assert_eq!(s.drain_timeout_ms, 5_000);
        assert_eq!(s.breaker_threshold, 8);
        s.apply_override("admit_rate", "2000").unwrap();
        s.apply_override("admit_burst", "4096").unwrap();
        s.apply_override("shed_watermark_pct", "50").unwrap();
        s.apply_override("watchdog_ms", "250").unwrap();
        s.apply_override("drain_timeout_ms", "1000").unwrap();
        s.apply_override("breaker_threshold", "2").unwrap();
        s.apply_override("breaker_window", "16").unwrap();
        s.apply_override("breaker_cooldown", "8").unwrap();
        assert_eq!(s.admit_rate, 2000.0);
        assert_eq!(s.admit_burst, 4096.0);
        assert_eq!(s.shed_watermark_pct, 50);
        assert_eq!(s.watchdog_ms, 250);
        assert_eq!(s.drain_timeout_ms, 1000);
        assert_eq!(s.breaker_threshold, 2);
        assert_eq!(s.breaker_window, 16);
        assert_eq!(s.breaker_cooldown, 8);
        assert!(s.apply_override("shed_watermark_pct", "0").is_err());
        assert!(s.apply_override("shed_watermark_pct", "101").is_err());
        assert!(s.apply_override("admit_rate", "fast").is_err());
        // Malformed fault specs surface their typed reason.
        let e = s.apply_override("faults", "slow@5x").unwrap_err();
        assert!(e.to_string().contains("slow@5x"), "{e}");
    }

    #[test]
    fn staging_overrides() {
        let mut s = ServingConfig::default();
        assert!(s.stage_delta, "delta staging is on by default");
        assert_eq!(s.stage_workers, 1, "staging is serial by default");
        s.apply_override("stage_delta", "off").unwrap();
        assert!(!s.stage_delta);
        s.apply_override("stage_delta", "1").unwrap();
        assert!(s.stage_delta);
        assert!(s.apply_override("stage_delta", "maybe").is_err());
        s.apply_override("stage_workers", "4").unwrap();
        assert_eq!(s.stage_workers, 4);
        assert!(s.apply_override("stage_workers", "0").is_err());
        assert!(s.apply_override("stage_workers", "many").is_err());
    }

    #[test]
    fn durability_overrides() {
        let mut s = ServingConfig::default();
        assert!(s.journal_dir.is_empty(), "journaling is off by default");
        assert_eq!(s.journal_fsync_every, 8);
        assert_eq!(s.checkpoint_interval_steps, 256);
        s.apply_override("journal_dir", "/tmp/radar-journal").unwrap();
        assert_eq!(s.journal_dir, "/tmp/radar-journal");
        s.apply_override("journal_fsync_every", "1").unwrap();
        assert_eq!(s.journal_fsync_every, 1);
        assert!(s.apply_override("journal_fsync_every", "0").is_err());
        assert!(s.apply_override("journal_fsync_every", "lots").is_err());
        s.apply_override("checkpoint_interval_steps", "0").unwrap();
        assert_eq!(s.checkpoint_interval_steps, 0, "0 disables checkpoints");
        s.apply_override("checkpoint_interval_steps", "64").unwrap();
        assert_eq!(s.checkpoint_interval_steps, 64);
        assert!(s.apply_override("checkpoint_interval_steps", "-1").is_err());
        // The crash fault kind parses through the faults override.
        s.apply_override("faults", "crash@6:2").unwrap();
        assert_eq!(s.faults.as_ref().unwrap().events.len(), 1);
    }

    #[test]
    fn artifact_paths() {
        let p = ArtifactPaths::new("/tmp/a", "sm");
        assert!(p.hlo("decode_b1_s128_n128").ends_with("sm/decode_b1_s128_n128.hlo.txt"));
        assert!(p.omega(64).ends_with("sm/omega_n64.npz"));
    }
}
