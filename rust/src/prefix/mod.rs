//! Shared-prefix KV reuse: a token-hash radix tree over block-granular
//! prompt prefixes.
//!
//! Sessions that share a prompt prefix (system prompts, few-shot
//! templates, multi-turn chats) would otherwise recompute identical KV
//! blocks *and* identical Radar segment summaries — both are pure
//! functions of the prefix tokens. This tree maps each
//! `BLOCK_TOKENS`-sized prompt chunk to an immutable, refcounted KV
//! block; a path from the root is a cached prefix. Nodes additionally
//! carry frozen [`FrozenSegments`] snapshots so a warm sequence's first
//! restructure can adopt precomputed segment means.
//!
//! Ownership: the tree holds exactly one `BlockPool` reference per
//! node. Sequences seeded from a match take their own references
//! (`SeqCache::seed_from_blocks`), so evicting a node while a session
//! still reads the block merely drops the tree's reference — the pool
//! frees a block only when *every* owner has released it. Shared blocks
//! are never written in place: they are always full, and the
//! copy-on-write tail logic in `SeqCache` covers the partial-block
//! case defensively.
//!
//! Eviction is LRU over *leaf* nodes (interior nodes are pinned by
//! their descendants) under a byte budget, preferring leaves no live
//! session shares.

use crate::kvcache::{BlockPool, BLOCK_TOKENS};
use crate::radar::FrozenSegments;
use anyhow::Result;
use std::sync::Arc;

/// FNV/splitmix-style fold of one block's tokens. Collisions are
/// tolerable: every hash match is verified against the stored tokens.
fn hash_block(tokens: &[i32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &t in tokens {
        h ^= t as u32 as u64;
        h = h.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        h ^= h >> 29;
    }
    h
}

struct Node {
    /// The BLOCK_TOKENS tokens this edge covers (exact verification —
    /// hashes only prune the search).
    tokens: Vec<i32>,
    hash: u64,
    /// KV block backing these tokens; the tree owns one reference.
    block: usize,
    parent: usize,
    children: Vec<usize>,
    /// Logical timestamp of the last probe/insert touching this node.
    last_used: u64,
    /// Frozen Radar segment means covering the root→here path
    /// (boundary <= depth * BLOCK_TOKENS by construction).
    frozen: Option<Arc<FrozenSegments>>,
}

/// Result of probing the tree with a prompt.
#[derive(Default)]
pub struct PrefixMatch {
    /// Matched KV blocks, root-first. NOT yet retained — seed a
    /// `SeqCache` from them (which takes references) before any
    /// eviction can run.
    pub blocks: Vec<usize>,
    /// Tokens covered (== blocks.len() * BLOCK_TOKENS).
    pub tokens: usize,
    /// Deepest frozen segment snapshot on the matched path, if any.
    pub frozen: Option<Arc<FrozenSegments>>,
}

/// Radix tree over block-granular prompt prefixes.
pub struct PrefixIndex {
    /// Slab; index 0 is the sentinel root (empty tokens, no block).
    nodes: Vec<Option<Node>>,
    free_slots: Vec<usize>,
    /// Live nodes excluding the root.
    n_nodes: usize,
    /// Byte budget over cached KV blocks (plus frozen summaries).
    budget_bytes: usize,
    /// Bytes per KV block (from `BlockPool::block_bytes`).
    block_bytes: usize,
    clock: u64,
    /// Telemetry: nodes evicted over the index lifetime.
    pub evictions: u64,
}

impl PrefixIndex {
    pub fn new(budget_bytes: usize, block_bytes: usize) -> Self {
        let root = Node {
            tokens: Vec::new(),
            hash: 0,
            block: usize::MAX,
            parent: 0,
            children: Vec::new(),
            last_used: 0,
            frozen: None,
        };
        Self {
            nodes: vec![Some(root)],
            free_slots: Vec::new(),
            n_nodes: 0,
            budget_bytes,
            block_bytes,
            clock: 0,
            evictions: 0,
        }
    }

    fn node(&self, id: usize) -> &Node {
        self.nodes[id].as_ref().expect("dangling node id")
    }

    fn node_mut(&mut self, id: usize) -> &mut Node {
        self.nodes[id].as_mut().expect("dangling node id")
    }

    /// Child of `id` whose edge equals `tokens` (hash-pruned, then
    /// verified exactly).
    fn find_child(&self, id: usize, hash: u64, tokens: &[i32]) -> Option<usize> {
        self.node(id)
            .children
            .iter()
            .copied()
            .find(|&c| self.node(c).hash == hash && self.node(c).tokens == tokens)
    }

    /// Longest cached prefix of `prompt`, capped at `limit` tokens.
    /// Touches every matched node's LRU timestamp.
    pub fn probe(&mut self, prompt: &[i32], limit: usize) -> PrefixMatch {
        self.clock += 1;
        let clock = self.clock;
        let max_blocks = prompt.len().min(limit) / BLOCK_TOKENS;
        let mut m = PrefixMatch::default();
        let mut cur = 0usize;
        for b in 0..max_blocks {
            let chunk = &prompt[b * BLOCK_TOKENS..(b + 1) * BLOCK_TOKENS];
            let Some(child) = self.find_child(cur, hash_block(chunk), chunk) else {
                break;
            };
            let node = self.node_mut(child);
            node.last_used = clock;
            m.blocks.push(node.block);
            if let Some(f) = &node.frozen {
                m.frozen = Some(f.clone());
            }
            cur = child;
        }
        m.tokens = m.blocks.len() * BLOCK_TOKENS;
        m
    }

    /// Read-only variant of [`probe`](Self::probe): how many prompt
    /// tokens would be served from cache. Used for admission ordering
    /// without perturbing LRU state.
    pub fn peek_match_tokens(&self, prompt: &[i32], limit: usize) -> usize {
        let max_blocks = prompt.len().min(limit) / BLOCK_TOKENS;
        let mut cur = 0usize;
        let mut matched = 0usize;
        for b in 0..max_blocks {
            let chunk = &prompt[b * BLOCK_TOKENS..(b + 1) * BLOCK_TOKENS];
            let Some(child) = self.find_child(cur, hash_block(chunk), chunk) else {
                break;
            };
            matched += 1;
            cur = child;
        }
        matched * BLOCK_TOKENS
    }

    /// Register a finished prefill: `blocks[b]` backs prompt tokens
    /// `[b*16, (b+1)*16)`. Only `blocks.len()` full chunks of `prompt`
    /// are inserted; new nodes retain their block in `pool`. `frozen`
    /// (if any) attaches at the deepest node whose depth covers its
    /// boundary. Returns the number of nodes created.
    pub fn insert(
        &mut self,
        pool: &mut BlockPool,
        prompt: &[i32],
        blocks: &[usize],
        frozen: Option<Arc<FrozenSegments>>,
    ) -> usize {
        self.clock += 1;
        let clock = self.clock;
        let n_blocks = blocks.len().min(prompt.len() / BLOCK_TOKENS);
        let mut cur = 0usize;
        let mut created = 0usize;
        let mut depth_tokens = 0usize;
        let mut frozen = frozen;
        for b in 0..n_blocks {
            let chunk = &prompt[b * BLOCK_TOKENS..(b + 1) * BLOCK_TOKENS];
            let hash = hash_block(chunk);
            let child = match self.find_child(cur, hash, chunk) {
                Some(c) => c,
                None => {
                    pool.retain(blocks[b]);
                    let node = Node {
                        tokens: chunk.to_vec(),
                        hash,
                        block: blocks[b],
                        parent: cur,
                        children: Vec::new(),
                        last_used: clock,
                        frozen: None,
                    };
                    let id = match self.free_slots.pop() {
                        Some(slot) => {
                            self.nodes[slot] = Some(node);
                            slot
                        }
                        None => {
                            self.nodes.push(Some(node));
                            self.nodes.len() - 1
                        }
                    };
                    self.node_mut(cur).children.push(id);
                    self.n_nodes += 1;
                    created += 1;
                    id
                }
            };
            self.node_mut(child).last_used = clock;
            depth_tokens += BLOCK_TOKENS;
            // Attach the frozen summary at the shallowest node that
            // fully covers it; anyone matching this far shares all the
            // summarized tokens.
            if let Some(f) = &frozen {
                if f.boundary <= depth_tokens {
                    let slot = &mut self.node_mut(child).frozen;
                    let better = slot.as_ref().map_or(true, |old| f.boundary > old.boundary);
                    if better {
                        *slot = frozen.take();
                    } else {
                        frozen = None;
                    }
                }
            }
            cur = child;
        }
        created
    }

    /// KV bytes held by the tree (frozen summaries included).
    pub fn bytes_used(&self) -> usize {
        let frozen: usize = self
            .nodes
            .iter()
            .flatten()
            .filter_map(|n| n.frozen.as_ref().map(|f| f.bytes()))
            .sum();
        self.n_nodes * self.block_bytes + frozen
    }

    pub fn cached_blocks(&self) -> usize {
        self.n_nodes
    }

    /// Snapshot of the tree's shape for journal checkpoints: one
    /// `(block_hash, depth_in_blocks)` pair per cached node, sorted for
    /// deterministic comparison. KV blocks themselves do not survive a
    /// restart, so recovery rebuilds the tree by re-prefilling; the
    /// topology records what was cached at checkpoint time for
    /// observability and tests.
    pub fn topology(&self) -> Vec<(u64, u32)> {
        let mut out = Vec::with_capacity(self.n_nodes);
        for node in self.nodes.iter().skip(1).flatten() {
            let mut depth = 1u32;
            let mut cur = node.parent;
            while cur != 0 {
                depth += 1;
                cur = self.node(cur).parent;
            }
            out.push((node.hash, depth));
        }
        out.sort_unstable();
        out
    }

    /// Cached blocks currently also referenced by at least one live
    /// sequence (gauge).
    pub fn shared_blocks(&self, pool: &BlockPool) -> usize {
        self.nodes
            .iter()
            .skip(1)
            .flatten()
            .filter(|n| pool.ref_count(n.block) > 1)
            .count()
    }

    /// Evict LRU leaves until `bytes_used() <= budget`. Leaves no
    /// session shares go first; a shared leaf's eviction only drops the
    /// tree's reference — the pool keeps the block alive until every
    /// sequence using it exits. Returns the number of nodes evicted.
    pub fn evict_to_budget(&mut self, pool: &mut BlockPool) -> Result<usize> {
        let mut evicted = 0usize;
        while self.bytes_used() > self.budget_bytes && self.n_nodes > 0 {
            // Victim: among leaves, unshared before shared, then oldest.
            let victim = self
                .nodes
                .iter()
                .enumerate()
                .skip(1)
                .filter_map(|(i, n)| n.as_ref().map(|n| (i, n)))
                .filter(|(_, n)| n.children.is_empty())
                .min_by_key(|(_, n)| (pool.ref_count(n.block) > 1, n.last_used))
                .map(|(i, _)| i);
            let Some(id) = victim else { break };
            self.remove_leaf(pool, id)?;
            evicted += 1;
        }
        self.evictions += evicted as u64;
        Ok(evicted)
    }

    fn remove_leaf(&mut self, pool: &mut BlockPool, id: usize) -> Result<()> {
        let node = self.nodes[id].take().expect("dangling node id");
        debug_assert!(node.children.is_empty(), "evicting an interior node");
        let parent = node.parent;
        self.node_mut(parent).children.retain(|&c| c != id);
        pool.release(&[node.block])?;
        self.free_slots.push(id);
        self.n_nodes -= 1;
        Ok(())
    }

    /// Drop every cached node (shutdown / tests).
    pub fn clear(&mut self, pool: &mut BlockPool) -> Result<()> {
        loop {
            let leaf = self
                .nodes
                .iter()
                .enumerate()
                .skip(1)
                .filter_map(|(i, n)| n.as_ref().map(|n| (i, n)))
                .find(|(_, n)| n.children.is_empty())
                .map(|(i, _)| i);
            match leaf {
                Some(id) => self.remove_leaf(pool, id)?,
                None => break,
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::kvcache::SeqCache;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            d_head: 4,
            d_ffn: 16,
            n_feat: 8,
            max_train_len: 64,
            vocab: 16,
        }
    }

    fn pool() -> BlockPool {
        BlockPool::new(&cfg(), 8, 64)
    }

    /// Build a sequence of `t` tokens whose KV content encodes the
    /// token index (so block identity is checkable through reads).
    fn seq_of(pool: &mut BlockPool, t: usize) -> SeqCache {
        let mut seq = SeqCache::new(8);
        for tok in 0..t {
            let k: Vec<f32> = (0..16).map(|i| (tok * 100 + i) as f32).collect();
            let f = vec![0.0f32; 32];
            seq.append(pool, &k, &k.clone(), &f).unwrap();
        }
        seq
    }

    fn prompt(n: usize) -> Vec<i32> {
        (0..n as i32).collect()
    }

    #[test]
    fn hash_block_discriminates() {
        let a = prompt(16);
        let mut b = a.clone();
        b[7] += 1;
        assert_ne!(hash_block(&a), hash_block(&b));
        assert_eq!(hash_block(&a), hash_block(&prompt(16)));
    }

    #[test]
    fn probe_empty_tree_misses() {
        let mut idx = PrefixIndex::new(1 << 20, 100);
        let m = idx.probe(&prompt(64), 64);
        assert_eq!(m.tokens, 0);
        assert!(m.blocks.is_empty());
        assert_eq!(idx.peek_match_tokens(&prompt(64), 64), 0);
    }

    #[test]
    fn insert_then_probe_roundtrip() {
        let mut p = pool();
        let mut idx = PrefixIndex::new(1 << 20, p.block_bytes());
        let seq = seq_of(&mut p, 48); // 3 full blocks
        let toks = prompt(48);
        let created = idx.insert(&mut p, &toks, &seq.blocks, None);
        assert_eq!(created, 3);
        assert_eq!(idx.cached_blocks(), 3);
        // The tree took its own references.
        for &b in &seq.blocks {
            assert_eq!(p.ref_count(b), 2);
        }
        // Full match.
        let m = idx.probe(&toks, usize::MAX);
        assert_eq!(m.tokens, 48);
        assert_eq!(m.blocks, seq.blocks);
        // Shorter prompt matches its own prefix.
        let m = idx.probe(&toks[..32], usize::MAX);
        assert_eq!(m.tokens, 32);
        // Diverging prompt matches only the shared prefix.
        let mut fork = toks.clone();
        fork[20] = 999;
        let m = idx.probe(&fork, usize::MAX);
        assert_eq!(m.tokens, 16);
        assert_eq!(m.blocks, vec![seq.blocks[0]]);
        // peek agrees with probe and does not touch LRU state.
        assert_eq!(idx.peek_match_tokens(&fork, usize::MAX), 16);
    }

    #[test]
    fn probe_respects_token_limit() {
        let mut p = pool();
        let mut idx = PrefixIndex::new(1 << 20, p.block_bytes());
        let seq = seq_of(&mut p, 48);
        let toks = prompt(48);
        idx.insert(&mut p, &toks, &seq.blocks, None);
        // limit 47: only 2 full blocks may be served (the engine caps at
        // prompt_len - 1 so the last token always goes through decode).
        let m = idx.probe(&toks, 47);
        assert_eq!(m.tokens, 32);
    }

    #[test]
    fn reinsert_is_idempotent() {
        let mut p = pool();
        let mut idx = PrefixIndex::new(1 << 20, p.block_bytes());
        let seq_a = seq_of(&mut p, 32);
        let seq_b = seq_of(&mut p, 32); // same tokens, different blocks
        let toks = prompt(32);
        assert_eq!(idx.insert(&mut p, &toks, &seq_a.blocks, None), 2);
        assert_eq!(idx.insert(&mut p, &toks, &seq_b.blocks, None), 0);
        assert_eq!(idx.cached_blocks(), 2);
        // seq_b's blocks were NOT retained by the duplicate insert.
        for &b in &seq_b.blocks {
            assert_eq!(p.ref_count(b), 1);
        }
        // Probe resolves to the first insertion's blocks.
        assert_eq!(idx.probe(&toks, usize::MAX).blocks, seq_a.blocks);
    }

    #[test]
    fn branching_prefixes_share_the_common_part() {
        let mut p = pool();
        let mut idx = PrefixIndex::new(1 << 20, p.block_bytes());
        let a: Vec<i32> = (0..32).collect();
        let mut b = a.clone();
        b[20] = 777; // diverges in block 1
        let seq_a = seq_of(&mut p, 32);
        let seq_b = seq_of(&mut p, 32);
        idx.insert(&mut p, &a, &seq_a.blocks, None);
        let created = idx.insert(&mut p, &b, &seq_b.blocks, None);
        assert_eq!(created, 1, "only the diverging block is new");
        assert_eq!(idx.cached_blocks(), 3);
        // b's block 0 was deduplicated onto a's.
        assert_eq!(p.ref_count(seq_b.blocks[0]), 1);
        assert_eq!(p.ref_count(seq_b.blocks[1]), 2);
        assert_eq!(idx.probe(&b, usize::MAX).blocks, vec![seq_a.blocks[0], seq_b.blocks[1]]);
    }

    #[test]
    fn eviction_respects_budget_and_lru() {
        let mut p = pool();
        let bb = p.block_bytes();
        let mut idx = PrefixIndex::new(2 * bb, bb); // room for 2 blocks
        let seq = seq_of(&mut p, 48);
        let toks = prompt(48);
        idx.insert(&mut p, &toks, &seq.blocks, None);
        assert_eq!(idx.bytes_used(), 3 * bb);
        // Drop the tree's over-budget tail; the deepest leaf goes first.
        let freed = seq.blocks.clone();
        let n = idx.evict_to_budget(&mut p).unwrap();
        assert_eq!(n, 1);
        assert_eq!(idx.cached_blocks(), 2);
        assert!(idx.bytes_used() <= 2 * bb);
        assert_eq!(p.ref_count(freed[2]), 1, "tree ref dropped, seq ref stays");
        // Probe now only reaches depth 2.
        assert_eq!(idx.probe(&toks, usize::MAX).tokens, 32);
        assert_eq!(idx.evictions, 1);
    }

    #[test]
    fn eviction_prefers_unshared_leaves() {
        let mut p = pool();
        let bb = p.block_bytes();
        let mut idx = PrefixIndex::new(bb, bb); // room for 1 block
        // Two sibling single-block prefixes; "hot" is shared with a live
        // sequence, "cold" is tree-only. Despite "cold" being more
        // recently used, the unshared leaf must go first.
        let hot_toks: Vec<i32> = (100..116).collect();
        let cold_toks: Vec<i32> = (200..216).collect();
        let hot_seq = seq_of(&mut p, 16);
        let cold_seq = seq_of(&mut p, 16);
        idx.insert(&mut p, &hot_toks, &hot_seq.blocks, None);
        idx.insert(&mut p, &cold_toks, &cold_seq.blocks, None);
        // A live session holds hot's block; cold's session exits.
        let mut cold_seq = cold_seq;
        cold_seq.free(&mut p).unwrap();
        assert_eq!(p.ref_count(hot_seq.blocks[0]), 2);
        // Touch cold so plain LRU would evict hot.
        idx.probe(&cold_toks, usize::MAX);
        idx.evict_to_budget(&mut p).unwrap();
        assert_eq!(idx.cached_blocks(), 1);
        assert_eq!(idx.probe(&hot_toks, usize::MAX).tokens, 16, "shared leaf kept");
        assert_eq!(idx.probe(&cold_toks, usize::MAX).tokens, 0, "unshared leaf evicted");
    }

    #[test]
    fn evicting_shared_leaf_never_frees_live_block() {
        let mut p = pool();
        let bb = p.block_bytes();
        let mut idx = PrefixIndex::new(0, bb); // budget 0: evict everything
        let seq = seq_of(&mut p, 32);
        let toks = prompt(32);
        idx.insert(&mut p, &toks, &seq.blocks, None);
        let snapshot: Vec<f32> = seq.key(&p, 0, 0, 17).to_vec();
        let n = idx.evict_to_budget(&mut p).unwrap();
        assert_eq!(n, 2);
        assert_eq!(idx.cached_blocks(), 0);
        // The live sequence still owns its blocks and reads them intact.
        for &b in &seq.blocks {
            assert_eq!(p.ref_count(b), 1);
        }
        assert_eq!(seq.key(&p, 0, 0, 17), &snapshot[..]);
        // Free list must not contain the live blocks: allocating all
        // remaining capacity never hands back a live id.
        let live: std::collections::HashSet<usize> = seq.blocks.iter().copied().collect();
        while let Ok(id) = p.allocate() {
            assert!(!live.contains(&id), "allocator reissued live block {id}");
        }
    }

    #[test]
    fn frozen_attaches_at_covering_depth() {
        let mut p = pool();
        let mut idx = PrefixIndex::new(1 << 20, p.block_bytes());
        let mut seq = seq_of(&mut p, 48);
        let toks = prompt(48);
        // Build a real frozen snapshot: c=6 over 36 tokens -> boundary 36.
        let mut ridx = crate::radar::RadarIndex::new(4, 8);
        ridx.maybe_restructure(&seq, &p, 36);
        let frozen = Arc::new(ridx.freeze(48).unwrap());
        assert_eq!(frozen.boundary, 36);
        idx.insert(&mut p, &toks, &seq.blocks, Some(frozen.clone()));
        // boundary 36 needs depth >= 3 blocks; a 2-block match must NOT
        // see it, a 3-block match must.
        let m = idx.probe(&toks[..32], usize::MAX);
        assert!(m.frozen.is_none(), "frozen leaked to a shallower match");
        let m = idx.probe(&toks, usize::MAX);
        let got = m.frozen.expect("frozen lost");
        assert_eq!(got.boundary, 36);
        assert_eq!(got.seg_feat(1, 2), frozen.seg_feat(1, 2));
        seq.free(&mut p).unwrap();
    }

    #[test]
    fn deeper_frozen_replaces_shallower() {
        let mut p = pool();
        let mut idx = PrefixIndex::new(1 << 20, p.block_bytes());
        let seq = seq_of(&mut p, 48);
        let toks = prompt(48);
        let mut r1 = crate::radar::RadarIndex::new(4, 8);
        r1.maybe_restructure(&seq, &p, 16); // c=4, boundary 16
        let mut r2 = crate::radar::RadarIndex::new(4, 8);
        r2.force_restructure(&seq, &p); // c=6, boundary 48
        idx.insert(&mut p, &toks[..16], &seq.blocks[..1], Some(Arc::new(r1.freeze(16).unwrap())));
        idx.insert(&mut p, &toks, &seq.blocks, Some(Arc::new(r2.freeze(48).unwrap())));
        let m = idx.probe(&toks, usize::MAX);
        assert_eq!(m.frozen.unwrap().boundary, 48, "deepest frozen wins");
        // Shallow probe still sees the shallow snapshot.
        let m = idx.probe(&toks[..16], usize::MAX);
        assert_eq!(m.frozen.unwrap().boundary, 16);
    }

    #[test]
    fn topology_reports_hash_and_depth_per_node() {
        let mut p = pool();
        let mut idx = PrefixIndex::new(1 << 20, p.block_bytes());
        assert!(idx.topology().is_empty());
        let a: Vec<i32> = (0..32).collect();
        let mut b = a.clone();
        b[20] = 777; // diverges in block 1
        let seq_a = seq_of(&mut p, 32);
        let seq_b = seq_of(&mut p, 32);
        idx.insert(&mut p, &a, &seq_a.blocks, None);
        idx.insert(&mut p, &b, &seq_b.blocks, None);
        let topo = idx.topology();
        assert_eq!(topo.len(), 3, "shared root block + two diverging children");
        let depths: Vec<u32> = {
            let mut d: Vec<u32> = topo.iter().map(|&(_, d)| d).collect();
            d.sort_unstable();
            d
        };
        assert_eq!(depths, vec![1, 2, 2]);
        let shared = hash_block(&a[..16]);
        assert!(topo.iter().any(|&(h, d)| h == shared && d == 1));
        // Deterministic: same tree, same snapshot.
        assert_eq!(idx.topology(), topo);
    }

    #[test]
    fn clear_releases_everything() {
        let mut p = pool();
        let mut idx = PrefixIndex::new(1 << 20, p.block_bytes());
        let mut seq = seq_of(&mut p, 48);
        idx.insert(&mut p, &prompt(48), &seq.blocks, None);
        seq.free(&mut p).unwrap();
        idx.clear(&mut p).unwrap();
        assert_eq!(idx.cached_blocks(), 0);
        assert_eq!(idx.bytes_used(), 0);
        assert_eq!(p.used_blocks(), 0, "all blocks returned to the pool");
    }
}
