//! Metric scorers mirroring LongBench's per-task metrics:
//! token-level F1 (QA), LCS-based Rouge-L (summarization), exact
//! accuracy (synthetic/few-shot), and edit similarity (code).

/// Whitespace token F1 between prediction and reference (QA metric).
pub fn qa_f1(pred: &str, reference: &str) -> f64 {
    let p: Vec<&str> = pred.split_whitespace().collect();
    let r: Vec<&str> = reference.split_whitespace().collect();
    if p.is_empty() || r.is_empty() {
        return if p.is_empty() && r.is_empty() { 1.0 } else { 0.0 };
    }
    let mut rcount = std::collections::HashMap::new();
    for w in &r {
        *rcount.entry(*w).or_insert(0usize) += 1;
    }
    let mut overlap = 0usize;
    for w in &p {
        if let Some(c) = rcount.get_mut(w) {
            if *c > 0 {
                *c -= 1;
                overlap += 1;
            }
        }
    }
    if overlap == 0 {
        return 0.0;
    }
    let prec = overlap as f64 / p.len() as f64;
    let rec = overlap as f64 / r.len() as f64;
    2.0 * prec * rec / (prec + rec)
}

/// Longest common subsequence length (word-level).
fn lcs(a: &[&str], b: &[&str]) -> usize {
    let mut dp = vec![0usize; b.len() + 1];
    for &wa in a {
        let mut prev = 0usize;
        for (j, &wb) in b.iter().enumerate() {
            let cur = dp[j + 1];
            dp[j + 1] = if wa == wb { prev + 1 } else { dp[j + 1].max(dp[j]) };
            prev = cur;
        }
    }
    dp[b.len()]
}

/// Rouge-L F-measure (word-level LCS), the summarization metric.
pub fn rouge_l(pred: &str, reference: &str) -> f64 {
    let p: Vec<&str> = pred.split_whitespace().collect();
    let r: Vec<&str> = reference.split_whitespace().collect();
    if p.is_empty() || r.is_empty() {
        return if p.is_empty() && r.is_empty() { 1.0 } else { 0.0 };
    }
    let l = lcs(&p, &r) as f64;
    if l == 0.0 {
        return 0.0;
    }
    let prec = l / p.len() as f64;
    let rec = l / r.len() as f64;
    2.0 * prec * rec / (prec + rec)
}

/// Exact-match accuracy after trimming (synthetic / few-shot metric).
pub fn exact(pred: &str, reference: &str) -> f64 {
    if pred.trim() == reference.trim() {
        1.0
    } else {
        0.0
    }
}

/// Substring accuracy: reference appears anywhere in the prediction
/// (LongBench uses this for retrieval-style tasks).
pub fn contains(pred: &str, reference: &str) -> f64 {
    if pred.contains(reference.trim()) {
        1.0
    } else {
        0.0
    }
}

/// Levenshtein edit similarity in [0, 1] (code metric).
pub fn edit_sim(pred: &str, reference: &str) -> f64 {
    let a: Vec<char> = pred.chars().collect();
    let b: Vec<char> = reference.chars().collect();
    if a.is_empty() && b.is_empty() {
        return 1.0;
    }
    let mut dp: Vec<usize> = (0..=b.len()).collect();
    for (i, &ca) in a.iter().enumerate() {
        let mut prev = dp[0];
        dp[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let cur = dp[j + 1];
            dp[j + 1] = if ca == cb {
                prev
            } else {
                1 + prev.min(dp[j]).min(dp[j + 1])
            };
            prev = cur;
        }
    }
    1.0 - dp[b.len()] as f64 / a.len().max(b.len()) as f64
}

/// Average percentile rank of each method's scores within a task row
/// (the paper's Table 1 "Avg. Perc." column): for method m, the
/// fraction of other methods it strictly beats, averaged over tasks.
pub fn percentile_ranks(rows: &[Vec<f64>]) -> Vec<f64> {
    // rows[task][method]
    if rows.is_empty() {
        return Vec::new();
    }
    let m = rows[0].len();
    let mut out = vec![0.0f64; m];
    for row in rows {
        for i in 0..m {
            let beaten = (0..m).filter(|&j| j != i && row[i] > row[j]).count();
            out[i] += beaten as f64 / (m - 1).max(1) as f64;
        }
    }
    for o in &mut out {
        *o = *o / rows.len() as f64 * 100.0;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f1_cases() {
        assert_eq!(qa_f1("the cat", "the cat"), 1.0);
        assert_eq!(qa_f1("dog", "cat"), 0.0);
        let f = qa_f1("the black cat", "the cat");
        assert!(f > 0.7 && f < 1.0);
    }

    #[test]
    fn rouge_cases() {
        assert_eq!(rouge_l("a b c", "a b c"), 1.0);
        assert!(rouge_l("a x b y c", "a b c") > 0.7);
        assert_eq!(rouge_l("z", "a b"), 0.0);
    }

    #[test]
    fn exact_and_contains() {
        assert_eq!(exact(" v17 ", "v17"), 1.0);
        assert_eq!(exact("v17x", "v17"), 0.0);
        assert_eq!(contains("answer: v17.", "v17"), 1.0);
        assert_eq!(contains("nope", "v17"), 0.0);
    }

    #[test]
    fn edit_sim_cases() {
        assert_eq!(edit_sim("abc", "abc"), 1.0);
        assert!((edit_sim("abc", "abd") - (1.0 - 1.0 / 3.0)).abs() < 1e-9);
        assert_eq!(edit_sim("", ""), 1.0);
        assert!(edit_sim("abcd", "") < 0.01);
    }

    #[test]
    fn percentile_ranks_ordering() {
        // 2 tasks, 3 methods; method 2 always best, 0 always worst.
        let rows = vec![vec![1.0, 5.0, 9.0], vec![0.1, 0.5, 0.9]];
        let p = percentile_ranks(&rows);
        assert_eq!(p, vec![0.0, 50.0, 100.0]);
    }
}
