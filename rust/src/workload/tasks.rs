//! LongBench-S: 16 deterministic synthetic subtasks across the same 6
//! categories as LongBench (single-doc QA, multi-doc QA, summarization,
//! few-shot, synthetic, code). Each instance is (prompt, reference,
//! metric); prompts are built from the same surface forms the models
//! were trained on (`<<kNN:vMM>>` bindings, `def fn_NN`), so answers
//! require *retaining the middle of the context* — exactly what
//! separates Radar from eviction baselines.

use super::score;
use crate::util::prng::SplitMix64;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Metric {
    QaF1,
    RougeL,
    Exact,
    Contains,
    EditSim,
}

impl Metric {
    pub fn score(&self, pred: &str, reference: &str) -> f64 {
        match self {
            Metric::QaF1 => score::qa_f1(pred, reference),
            Metric::RougeL => score::rouge_l(pred, reference),
            Metric::Exact => score::exact(pred, reference),
            Metric::Contains => score::contains(pred, reference),
            Metric::EditSim => score::edit_sim(pred, reference),
        }
    }
}

#[derive(Debug, Clone)]
pub struct TaskInstance {
    pub prompt: Vec<u8>,
    pub reference: String,
    pub max_new_tokens: usize,
}

#[derive(Debug, Clone, Copy)]
pub struct TaskSpec {
    pub name: &'static str,
    pub category: &'static str,
    pub metric: Metric,
}

pub const TASKS: [TaskSpec; 16] = [
    TaskSpec { name: "NrtvQA-S", category: "single_qa", metric: Metric::QaF1 },
    TaskSpec { name: "Qasper-S", category: "single_qa", metric: Metric::QaF1 },
    TaskSpec { name: "MFQA-S", category: "single_qa", metric: Metric::QaF1 },
    TaskSpec { name: "HtptQA-S", category: "multi_qa", metric: Metric::QaF1 },
    TaskSpec { name: "2WkQA-S", category: "multi_qa", metric: Metric::QaF1 },
    TaskSpec { name: "Musique-S", category: "multi_qa", metric: Metric::QaF1 },
    TaskSpec { name: "GovRep-S", category: "summarization", metric: Metric::RougeL },
    TaskSpec { name: "QMSum-S", category: "summarization", metric: Metric::RougeL },
    TaskSpec { name: "MulNews-S", category: "summarization", metric: Metric::RougeL },
    TaskSpec { name: "TREC-S", category: "few_shot", metric: Metric::Exact },
    TaskSpec { name: "TrivQA-S", category: "few_shot", metric: Metric::QaF1 },
    TaskSpec { name: "SamSum-S", category: "few_shot", metric: Metric::RougeL },
    TaskSpec { name: "PsgCnt-S", category: "synthetic", metric: Metric::Exact },
    TaskSpec { name: "PsgRet-S", category: "synthetic", metric: Metric::Contains },
    TaskSpec { name: "TCC-S", category: "code", metric: Metric::EditSim },
    TaskSpec { name: "RB-P-S", category: "code", metric: Metric::EditSim },
];

/// Filler prose shared by generators (cheap, deterministic).
fn filler(rng: &mut SplitMix64, n: usize) -> Vec<u8> {
    const WORDS: [&str; 12] = [
        "the", "stream", "carries", "old", "light", "towards", "dawn",
        "quiet", "hills", "answer", "slowly", "wind",
    ];
    let mut out = Vec::with_capacity(n + 8);
    while out.len() < n {
        out.extend_from_slice(WORDS[rng.below(12) as usize].as_bytes());
        out.push(b' ');
        if rng.below(12) == 0 {
            out.extend_from_slice(b". ");
        }
    }
    out.truncate(n);
    out
}

fn binding(rng: &mut SplitMix64) -> (String, String) {
    (format!("k{:02}", rng.below(64)), format!("v{:02}", rng.below(64)))
}

fn bind_str(k: &str, v: &str) -> String {
    format!(" <<{k}={v}>> ")
}

fn probe_str(k: &str) -> String {
    format!("<<{k}=")
}

/// Generate one instance of task `spec` with context ~`ctx_len` bytes.
pub fn generate(spec: &TaskSpec, ctx_len: usize, seed: u64) -> TaskInstance {
    let mut rng = SplitMix64::new(seed ^ fxhash(spec.name));
    match spec.category {
        "single_qa" => single_qa(&mut rng, ctx_len, spec.name),
        "multi_qa" => multi_qa(&mut rng, ctx_len),
        "summarization" => summarization(&mut rng, ctx_len),
        "few_shot" => few_shot(&mut rng, ctx_len, spec.name),
        "synthetic" => synthetic(&mut rng, ctx_len, spec.name),
        "code" => code(&mut rng, ctx_len, spec.name),
        _ => unreachable!(),
    }
}

fn fxhash(s: &str) -> u64 {
    s.bytes().fold(0xcbf29ce484222325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100000001b3)
    })
}

/// One binding planted mid-context; probe at the end. The three
/// single-QA variants differ in planting depth (shallow / middle / deep).
fn single_qa(rng: &mut SplitMix64, ctx_len: usize, name: &str) -> TaskInstance {
    let (k, v) = binding(rng);
    let depth_frac = match name {
        "NrtvQA-S" => 0.25, // deep (near the start)
        "Qasper-S" => 0.5,
        _ => 0.75,          // shallow (near the end)
    };
    let mut ctx = filler(rng, ctx_len);
    let at = ((ctx.len() as f64 * depth_frac) as usize).min(ctx.len());
    let bind = bind_str(&k, &v);
    ctx.splice(at..at, bind.bytes());
    let mut prompt = ctx;
    prompt.extend_from_slice(probe_str(&k).as_bytes());
    TaskInstance { prompt, reference: v, max_new_tokens: 4 }
}

/// Several bindings spread across "documents"; the probe asks for two
/// of them (both must be retained).
fn multi_qa(rng: &mut SplitMix64, ctx_len: usize) -> TaskInstance {
    let n_docs = 4;
    let mut bindings = Vec::new();
    let mut prompt = Vec::new();
    for d in 0..n_docs {
        prompt.extend_from_slice(format!("[doc {d}] ").as_bytes());
        let mut body = filler(rng, ctx_len / n_docs - 24);
        let (k, v) = binding(rng);
        let at = body.len() / 2;
        body.splice(at..at, bind_str(&k, &v).bytes());
        prompt.extend_from_slice(&body);
        bindings.push((k, v));
    }
    let (k1, v1) = bindings[rng.below(2) as usize].clone();
    let (k2, v2) = bindings[2 + rng.below(2) as usize].clone();
    prompt.extend_from_slice(probe_str(&k1).as_bytes());
    // Model answers v1; harness appends and re-asks for v2 — encoded as
    // one instance whose reference is both values; generation length
    // covers "v1 <<k2?>>v2" won't be produced unaided, so the reference
    // is just v1 and v2 both checked by F1 over the continuation
    // "v1" (primary) — we keep both words so partial credit applies.
    let _ = (k2, &v2);
    TaskInstance { prompt, reference: format!("{v1} {v2}"), max_new_tokens: 4 }
}

/// Context with N bindings; the "summary" is all values in order.
fn summarization(rng: &mut SplitMix64, ctx_len: usize) -> TaskInstance {
    let n = 4;
    let mut prompt = Vec::new();
    let mut values = Vec::new();
    for _ in 0..n {
        let mut body = filler(rng, ctx_len / n - 16);
        let (k, v) = binding(rng);
        let at = body.len() / 2;
        body.splice(at..at, bind_str(&k, &v).bytes());
        prompt.extend_from_slice(&body);
        values.push((k, v));
    }
    // Ask for the first bound value as the summary lead; reference
    // includes all values (Rouge-L grants partial credit).
    let (k0, _) = values[0].clone();
    prompt.extend_from_slice(probe_str(&k0).as_bytes());
    let reference = values.iter().map(|(_, v)| v.as_str()).collect::<Vec<_>>().join(" ");
    TaskInstance { prompt, reference, max_new_tokens: 8 }
}

/// In-context mapping defined by examples early in the prompt, probed
/// at the end (mapping must survive the middle filler).
fn few_shot(rng: &mut SplitMix64, ctx_len: usize, name: &str) -> TaskInstance {
    let (k, v) = binding(rng);
    let mut prompt = Vec::new();
    // "Examples" = repeated demonstrations of the binding.
    let reps = if name == "TREC-S" { 3 } else { 2 };
    for _ in 0..reps {
        prompt.extend_from_slice(format!("<<{k}={v}>> <<{k}={v}>> ").as_bytes());
    }
    let used = prompt.len();
    prompt.extend(filler(rng, ctx_len.saturating_sub(used + 10)));
    prompt.extend_from_slice(probe_str(&k).as_bytes());
    TaskInstance { prompt, reference: v, max_new_tokens: 4 }
}

/// PsgCnt: count marker occurrences; PsgRet: which passage holds the key.
fn synthetic(rng: &mut SplitMix64, ctx_len: usize, name: &str) -> TaskInstance {
    if name == "PsgCnt-S" {
        let n = 2 + rng.below(6) as usize;
        let mut prompt = Vec::new();
        let seg = ctx_len / (n + 1);
        for i in 0..n {
            prompt.extend(filler(rng, seg.saturating_sub(8)));
            prompt.extend_from_slice(format!("@@{i} ").as_bytes());
        }
        prompt.extend_from_slice(b" count:@@");
        TaskInstance {
            prompt,
            reference: format!("{}", n - 1),
            max_new_tokens: 2,
        }
    } else {
        let n_pass = 4;
        let target = rng.below(n_pass) as usize;
        let (k, v) = binding(rng);
        let mut prompt = Vec::new();
        for p in 0..n_pass as usize {
            prompt.extend_from_slice(format!("[p{p}] ").as_bytes());
            let mut body = filler(rng, ctx_len / n_pass as usize - 16);
            if p == target {
                let at = body.len() / 2;
                body.splice(at..at, bind_str(&k, &v).bytes());
            }
            prompt.extend(body);
        }
        prompt.extend_from_slice(probe_str(&k).as_bytes());
        TaskInstance { prompt, reference: v, max_new_tokens: 4 }
    }
}

/// Code: recall a function's return value from its (distant) definition.
fn code(rng: &mut SplitMix64, ctx_len: usize, name: &str) -> TaskInstance {
    let fname = format!("fn_{:02}", rng.below(90));
    let val = rng.below(90);
    let def = format!("def {fname}(x):\n    y = 1 + 2\n    return {val}\n");
    let mut prompt = Vec::new();
    let depth = if name == "TCC-S" { 0.3 } else { 0.6 };
    let mut body = filler(rng, ctx_len.saturating_sub(def.len() + 24));
    let at = (body.len() as f64 * depth) as usize;
    body.splice(at..at, def.bytes());
    prompt.extend(body);
    prompt.extend_from_slice(format!("z = {fname}(7)  # -> ").as_bytes());
    TaskInstance {
        prompt,
        reference: format!("{val}"),
        max_new_tokens: 3,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tasks_generate_and_are_deterministic() {
        for spec in &TASKS {
            let a = generate(spec, 1024, 7);
            let b = generate(spec, 1024, 7);
            assert_eq!(a.prompt, b.prompt, "{}", spec.name);
            assert_eq!(a.reference, b.reference);
            assert!(!a.reference.is_empty());
            assert!(a.prompt.len() >= 700 && a.prompt.len() <= 1300,
                "{}: len {}", spec.name, a.prompt.len());
            assert!(a.max_new_tokens > 0);
        }
    }

    #[test]
    fn single_qa_probe_matches_binding() {
        let inst = generate(&TASKS[0], 2048, 3);
        let text = String::from_utf8_lossy(&inst.prompt);
        let probe_key = text.rfind("<<k").map(|i| &text[i + 2..i + 5]).unwrap();
        assert!(text.contains(&format!("<<{probe_key}={}>>", inst.reference)));
        assert!(text.ends_with(&format!("<<{probe_key}=")));
    }

    #[test]
    fn single_qa_depths_differ() {
        let pos = |name: &str| {
            let spec = TASKS.iter().find(|t| t.name == name).unwrap();
            let inst = generate(spec, 4096, 5);
            let text = String::from_utf8_lossy(&inst.prompt).into_owned();
            text.find("<<k").unwrap() as f64 / text.len() as f64
        };
        assert!(pos("NrtvQA-S") < pos("Qasper-S"));
        assert!(pos("Qasper-S") < pos("MFQA-S"));
    }

    #[test]
    fn psgcnt_counts_markers() {
        let spec = TASKS.iter().find(|t| t.name == "PsgCnt-S").unwrap();
        let inst = generate(spec, 2048, 11);
        let text = String::from_utf8_lossy(&inst.prompt);
        let markers = text.matches("@@").count() - 1; // minus the probe
        let want: usize = inst.reference.parse::<usize>().unwrap() + 1;
        assert_eq!(markers, want);
    }

    #[test]
    fn code_task_def_precedes_call() {
        let spec = TASKS.iter().find(|t| t.name == "TCC-S").unwrap();
        let inst = generate(spec, 2048, 13);
        let text = String::from_utf8_lossy(&inst.prompt);
        let def = text.find("def fn_").unwrap();
        let call = text.rfind("z = fn_").unwrap();
        assert!(def < call);
        assert!(text.contains(&format!("return {}", inst.reference)));
    }

    #[test]
    fn sixteen_tasks_six_categories() {
        let cats: std::collections::HashSet<_> =
            TASKS.iter().map(|t| t.category).collect();
        assert_eq!(TASKS.len(), 16);
        assert_eq!(cats.len(), 6);
    }
}
