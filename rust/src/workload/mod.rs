//! Workloads: evaluation corpora readers, needle tests, the
//! LongBench-S synthetic benchmark (16 subtasks / 6 categories), and
//! metric scorers — the rust-side substitutes for PG-19 / The Stack /
//! LongBench (DESIGN.md §4).

pub mod score;
pub mod tasks;

use crate::config::ArtifactPaths;
use anyhow::{Context, Result};

/// Byte corpus dumped by `python/compile/data.py` at `make artifacts`.
pub fn load_corpus(paths: &ArtifactPaths, name: &str) -> Result<Vec<u8>> {
    let p = paths.corpus(name);
    std::fs::read(&p).with_context(|| format!("reading corpus {p:?} (run `make artifacts`)"))
}

/// Needle-in-a-haystack workload: filler text with one key/value
/// binding planted `depth_back` bytes before the end, followed by the
/// probe. The model must emit the value; eviction policies that drop
/// the binding fail. Uses the training corpus' exact `<<kNN:vMM>>`
/// surface form so trained models recognize it.
pub struct Needle {
    pub prompt: Vec<u8>,
    pub answer: String,
}

pub fn make_needle(filler: &[u8], total_len: usize, depth_back: usize, seed: u64) -> Needle {
    use crate::util::prng::SplitMix64;
    let mut rng = SplitMix64::new(seed);
    let key = format!("k{:02}", rng.below(64));
    let val = format!("v{:02}", rng.below(64));
    let binding = format!("<<{key}={val}>> ");
    let probe = format!("<<{key}=");
    let body_len = total_len.saturating_sub(probe.len());
    let mut prompt = Vec::with_capacity(total_len);
    let start = (rng.below(1024) as usize) % filler.len().max(1);
    let insert_at = body_len.saturating_sub(depth_back.min(body_len - binding.len()));
    while prompt.len() < body_len {
        let i = (start + prompt.len()) % filler.len();
        // Splice the binding at the target depth.
        if prompt.len() == insert_at {
            prompt.extend_from_slice(binding.as_bytes());
        }
        prompt.push(filler[i]);
    }
    prompt.truncate(body_len);
    prompt.extend_from_slice(probe.as_bytes());
    Needle { prompt, answer: val }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn needle_places_binding_at_depth() {
        let filler: Vec<u8> = (0..4096).map(|i| b'a' + (i % 26) as u8).collect();
        let n = make_needle(&filler, 2048, 700, 7);
        assert_eq!(n.prompt.len(), 2048);
        let text = String::from_utf8_lossy(&n.prompt);
        let bind_pos = text.find("<<k").unwrap();
        let probe_pos = text.rfind("<<k").unwrap();
        assert!(probe_pos > bind_pos);
        let distance = probe_pos - bind_pos;
        assert!(
            (550..900).contains(&distance),
            "binding should be ~700 bytes back, got {distance}"
        );
        assert!(text.ends_with("="));
    }

    #[test]
    fn needle_answer_matches_binding() {
        let filler: Vec<u8> = (0..4096).map(|i| b'x' + (i % 3) as u8).collect();
        let n = make_needle(&filler, 1024, 300, 9);
        let text = String::from_utf8_lossy(&n.prompt);
        let key_start = text.find("<<k").unwrap();
        let bound = &text[key_start..key_start + 12];
        assert!(bound.contains(&n.answer), "{bound} vs {}", n.answer);
    }

    #[test]
    fn needle_deterministic() {
        let filler: Vec<u8> = (0..1000).map(|i| b'a' + (i % 26) as u8).collect();
        let a = make_needle(&filler, 512, 100, 3);
        let b = make_needle(&filler, 512, 100, 3);
        assert_eq!(a.prompt, b.prompt);
        assert_eq!(a.answer, b.answer);
    }
}
