//! Deterministic fault injection for chaos testing the engine.
//!
//! A `FaultPlan` is a list of scripted events keyed by the engine's
//! 1-based step counter: allocation failures (surface as KV-cache
//! exhaustion and exercise the preemption path), step panics (exercise
//! per-sequence containment), slow steps (exercise deadlines), stalls
//! (exercise the watchdog), NaN poisoning of Radar segment summaries
//! (exercise the exact-attention fallback), and simulated hard aborts
//! (exercise journal-based crash recovery). Plans are either
//! written out explicitly (`alloc@5:2,panic@9`) or generated from a
//! seed (`seeded:42:100:6`) via `util::prng`, so a failing chaos run
//! reproduces bit-for-bit from its seed.

use crate::util::prng::SplitMix64;

/// What to inject. `seq: None` targets whichever sequence is queried
/// first at the scripted step (deterministic: queries follow id order).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Fail the next KV block allocation for the matching sequence.
    AllocFail { seq: Option<u64> },
    /// Panic inside the matching sequence's step body.
    StepPanic { seq: Option<u64> },
    /// Sleep this long before the step runs (deadline pressure).
    SlowStep { ms: u64 },
    /// Poison the matching sequence's Radar segment summaries with
    /// NaNs (anomaly-fallback pressure).
    NanInject { seq: Option<u64> },
    /// Sleep this long *inside* one sequence's step body (watchdog
    /// pressure: the stall is attributable to that sequence).
    Stall { ms: u64 },
    /// Simulated hard abort: the engine tears the journal at its last
    /// fsync boundary (unsynced records are lost, as in a real crash),
    /// fails all in-flight work, and goes idle. Recovery is exercised
    /// by reopening the journal directory.
    CrashAbort { seq: Option<u64> },
}

/// One scripted event, armed at a 1-based engine step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultEvent {
    pub step: u64,
    pub kind: FaultKind,
}

/// A malformed fault spec. Typed so config validation can surface the
/// precise reason instead of a stringly-typed parse failure.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
pub enum FaultSpecError {
    #[error("empty fault spec")]
    Empty,
    #[error("fault event {event:?} missing '@STEP'")]
    MissingStep { event: String },
    #[error("unknown fault kind {kind:?} in {event:?} (want alloc|panic|nan|crash|slow|stall)")]
    UnknownKind { kind: String, event: String },
    #[error("bad step in {event:?}: {reason}")]
    BadStep { event: String, reason: &'static str },
    #[error("bad sequence id in {event:?}: want an unsigned integer")]
    BadSeq { event: String },
    #[error("bad duration in {event:?}: want {kind}@STEPxMS with unsigned integer MS")]
    BadDuration { event: String, kind: &'static str },
    #[error("seeded spec wants seeded:SEED:HORIZON:COUNT with unsigned integers, got {spec:?}")]
    BadSeeded { spec: String },
}

/// A deterministic schedule of faults.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// Parse a plan spec.
    ///
    /// Grammar (comma-separated events):
    ///   alloc@STEP[:SEQ]   fail a block allocation at STEP
    ///   panic@STEP[:SEQ]   panic in a sequence's step body at STEP
    ///   nan@STEP[:SEQ]     poison Radar segment summaries at STEP
    ///   crash@STEP[:SEQ]   simulated hard abort at STEP (journal torn
    ///                      at its last fsync boundary)
    ///   slow@STEPxMS       sleep MS milliseconds before STEP
    ///   stall@STEPxMS      sleep MS inside one sequence's step body
    ///
    /// Or a whole-spec seeded form: `seeded:SEED:HORIZON:COUNT`.
    ///
    /// Malformed specs — a missing `@STEP`, step 0, negative or
    /// overflowing numbers, an unknown kind — are typed errors, never
    /// silently skipped.
    pub fn parse(spec: &str) -> Result<Self, FaultSpecError> {
        let spec = spec.trim();
        if spec.is_empty() {
            return Err(FaultSpecError::Empty);
        }
        if let Some(rest) = spec.strip_prefix("seeded:") {
            let bad = || FaultSpecError::BadSeeded { spec: spec.to_string() };
            let parts: Vec<&str> = rest.split(':').collect();
            if parts.len() != 3 {
                return Err(bad());
            }
            let seed: u64 = parts[0].parse().map_err(|_| bad())?;
            let horizon: u64 = parts[1].parse().map_err(|_| bad())?;
            let count: usize = parts[2].parse().map_err(|_| bad())?;
            return Ok(Self::seeded(seed, horizon, count));
        }
        let mut events = Vec::new();
        for ev in spec.split(',') {
            let ev = ev.trim();
            let (kind, rest) = ev
                .split_once('@')
                .ok_or_else(|| FaultSpecError::MissingStep { event: ev.to_string() })?;
            let parse_step = |s: &str| -> Result<u64, FaultSpecError> {
                let step: u64 = s.parse().map_err(|_| FaultSpecError::BadStep {
                    event: ev.to_string(),
                    reason: "want an unsigned integer",
                })?;
                if step == 0 {
                    return Err(FaultSpecError::BadStep {
                        event: ev.to_string(),
                        reason: "steps are 1-based, got 0",
                    });
                }
                Ok(step)
            };
            let event = match kind {
                "alloc" | "panic" | "nan" | "crash" => {
                    let (step_s, seq) = match rest.split_once(':') {
                        Some((st, sq)) => {
                            let sq: u64 = sq
                                .parse()
                                .map_err(|_| FaultSpecError::BadSeq { event: ev.to_string() })?;
                            (st, Some(sq))
                        }
                        None => (rest, None),
                    };
                    let step = parse_step(step_s)?;
                    let k = match kind {
                        "alloc" => FaultKind::AllocFail { seq },
                        "panic" => FaultKind::StepPanic { seq },
                        "crash" => FaultKind::CrashAbort { seq },
                        _ => FaultKind::NanInject { seq },
                    };
                    FaultEvent { step, kind: k }
                }
                "slow" | "stall" => {
                    let dur_kind = if kind == "slow" { "slow" } else { "stall" };
                    let bad = || FaultSpecError::BadDuration {
                        event: ev.to_string(),
                        kind: dur_kind,
                    };
                    let (step_s, ms_s) = rest.split_once('x').ok_or_else(bad)?;
                    let step = parse_step(step_s)?;
                    let ms: u64 = ms_s.parse().map_err(|_| bad())?;
                    let k = if kind == "slow" {
                        FaultKind::SlowStep { ms }
                    } else {
                        FaultKind::Stall { ms }
                    };
                    FaultEvent { step, kind: k }
                }
                other => {
                    return Err(FaultSpecError::UnknownKind {
                        kind: other.to_string(),
                        event: ev.to_string(),
                    })
                }
            };
            events.push(event);
        }
        events.sort_by_key(|e| e.step);
        Ok(Self { events })
    }

    /// Generate `count` faults uniformly over steps [1, horizon] from a
    /// seed. Same seed, same plan — chaos runs are replayable. Seeded
    /// plans draw only the three original kinds so historical seeds
    /// keep scripting the same faults; `nan@`/`stall@` are explicit.
    pub fn seeded(seed: u64, horizon: u64, count: usize) -> Self {
        let mut r = SplitMix64::new(seed);
        let mut events = Vec::with_capacity(count);
        for _ in 0..count {
            let step = r.below(horizon.max(1)) + 1;
            let kind = match r.below(3) {
                0 => FaultKind::AllocFail { seq: None },
                1 => FaultKind::StepPanic { seq: None },
                _ => FaultKind::SlowStep { ms: 1 + r.below(5) },
            };
            events.push(FaultEvent { step, kind });
        }
        events.sort_by_key(|e| e.step);
        Self { events }
    }
}

/// Runtime state: the plan plus one-shot fired flags. Owned by the
/// engine; each event fires at most once.
#[derive(Debug, Default)]
pub struct ActiveFaults {
    events: Vec<FaultEvent>,
    fired: Vec<bool>,
}

impl ActiveFaults {
    pub fn new(plan: Option<FaultPlan>) -> Self {
        let events = plan.map(|p| p.events).unwrap_or_default();
        let fired = vec![false; events.len()];
        Self { events, fired }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Consume a slow-step event armed at `step`, returning its delay.
    pub fn take_slow(&mut self, step: u64) -> Option<u64> {
        for (i, ev) in self.events.iter().enumerate() {
            if self.fired[i] || ev.step != step {
                continue;
            }
            if let FaultKind::SlowStep { ms } = ev.kind {
                self.fired[i] = true;
                return Some(ms);
            }
        }
        None
    }

    /// Consume a stall event armed at `step`, returning its delay. The
    /// engine calls this inside each sequence's step body, so the first
    /// sequence queried at the armed step owns the stall.
    pub fn take_stall(&mut self, step: u64) -> Option<u64> {
        for (i, ev) in self.events.iter().enumerate() {
            if self.fired[i] || ev.step != step {
                continue;
            }
            if let FaultKind::Stall { ms } = ev.kind {
                self.fired[i] = true;
                return Some(ms);
            }
        }
        None
    }

    /// Consume an allocation-failure event armed at `step` targeting
    /// `seq` (untargeted events match the first sequence queried).
    pub fn take_alloc(&mut self, step: u64, seq: u64) -> bool {
        self.take_targeted(step, seq, |k| match k {
            FaultKind::AllocFail { seq } => Some(seq),
            _ => None,
        })
    }

    /// Consume a panic event armed at `step` targeting `seq`.
    pub fn take_panic(&mut self, step: u64, seq: u64) -> bool {
        self.take_targeted(step, seq, |k| match k {
            FaultKind::StepPanic { seq } => Some(seq),
            _ => None,
        })
    }

    /// Consume a NaN-poisoning event armed at `step` targeting `seq`.
    pub fn take_nan(&mut self, step: u64, seq: u64) -> bool {
        self.take_targeted(step, seq, |k| match k {
            FaultKind::NanInject { seq } => Some(seq),
            _ => None,
        })
    }

    /// Consume a crash-abort event armed at `step` targeting `seq`.
    pub fn take_crash(&mut self, step: u64, seq: u64) -> bool {
        self.take_targeted(step, seq, |k| match k {
            FaultKind::CrashAbort { seq } => Some(seq),
            _ => None,
        })
    }

    /// `pick` extracts the target from matching kinds; `None` means
    /// the event is of a different kind.
    fn take_targeted(
        &mut self,
        step: u64,
        seq: u64,
        pick: impl Fn(FaultKind) -> Option<Option<u64>>,
    ) -> bool {
        for (i, ev) in self.events.iter().enumerate() {
            if self.fired[i] || ev.step != step {
                continue;
            }
            let Some(target) = pick(ev.kind) else { continue };
            let hit = match target {
                Some(t) => t == seq,
                None => true,
            };
            if hit {
                self.fired[i] = true;
                return true;
            }
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_explicit_events() {
        let p = FaultPlan::parse("alloc@5:2, panic@9, slow@12x50").unwrap();
        assert_eq!(
            p.events,
            vec![
                FaultEvent { step: 5, kind: FaultKind::AllocFail { seq: Some(2) } },
                FaultEvent { step: 9, kind: FaultKind::StepPanic { seq: None } },
                FaultEvent { step: 12, kind: FaultKind::SlowStep { ms: 50 } },
            ]
        );
    }

    #[test]
    fn parse_nan_and_stall_events() {
        let p = FaultPlan::parse("nan@4:2,stall@7x30,nan@9").unwrap();
        assert_eq!(
            p.events,
            vec![
                FaultEvent { step: 4, kind: FaultKind::NanInject { seq: Some(2) } },
                FaultEvent { step: 7, kind: FaultKind::Stall { ms: 30 } },
                FaultEvent { step: 9, kind: FaultKind::NanInject { seq: None } },
            ]
        );
    }

    #[test]
    fn parse_sorts_by_step() {
        let p = FaultPlan::parse("panic@9,alloc@3").unwrap();
        assert_eq!(p.events[0].step, 3);
        assert_eq!(p.events[1].step, 9);
    }

    #[test]
    fn parse_rejects_bad_specs_with_typed_errors() {
        use FaultSpecError as E;
        let err = |s: &str| FaultPlan::parse(s).unwrap_err();
        assert_eq!(err(""), E::Empty);
        assert_eq!(err("   "), E::Empty);
        assert_eq!(err("alloc"), E::MissingStep { event: "alloc".into() });
        assert_eq!(
            err("alloc@0"),
            E::BadStep { event: "alloc@0".into(), reason: "steps are 1-based, got 0" }
        );
        assert_eq!(
            err("alloc@x"),
            E::BadStep { event: "alloc@x".into(), reason: "want an unsigned integer" }
        );
        assert_eq!(
            err("alloc@-3"),
            E::BadStep { event: "alloc@-3".into(), reason: "want an unsigned integer" }
        );
        assert_eq!(
            err("panic@99999999999999999999"),
            E::BadStep {
                event: "panic@99999999999999999999".into(),
                reason: "want an unsigned integer"
            },
            "overflowing step must be rejected, not wrapped"
        );
        assert_eq!(err("alloc@"), E::BadStep {
            event: "alloc@".into(),
            reason: "want an unsigned integer"
        });
        assert_eq!(err("panic@3:-1"), E::BadSeq { event: "panic@3:-1".into() });
        assert_eq!(err("nan@2:x"), E::BadSeq { event: "nan@2:x".into() });
        assert_eq!(
            err("boom@3"),
            E::UnknownKind { kind: "boom".into(), event: "boom@3".into() }
        );
        assert_eq!(err("slow@5"), E::BadDuration { event: "slow@5".into(), kind: "slow" });
        assert_eq!(err("slow@5x"), E::BadDuration { event: "slow@5x".into(), kind: "slow" });
        assert_eq!(err("stall@5"), E::BadDuration { event: "stall@5".into(), kind: "stall" });
        assert_eq!(
            err("stall@5x-2"),
            E::BadDuration { event: "stall@5x-2".into(), kind: "stall" }
        );
        assert_eq!(err("seeded:1:2"), E::BadSeeded { spec: "seeded:1:2".into() });
        assert_eq!(err("seeded:1:2:x"), E::BadSeeded { spec: "seeded:1:2:x".into() });
        assert_eq!(err("seeded:-1:2:3"), E::BadSeeded { spec: "seeded:-1:2:3".into() });
        // One bad event poisons the whole spec — nothing is skipped.
        assert!(FaultPlan::parse("alloc@3,boom@4").is_err());
    }

    #[test]
    fn spec_errors_render_the_offending_event() {
        let msg = FaultPlan::parse("slow@5x").unwrap_err().to_string();
        assert!(msg.contains("slow@5x"), "error must quote the event: {msg}");
        let msg = FaultPlan::parse("boom@3").unwrap_err().to_string();
        assert!(msg.contains("boom"), "error must name the unknown kind: {msg}");
    }

    #[test]
    fn seeded_is_deterministic_and_in_range() {
        let a = FaultPlan::seeded(42, 100, 6);
        let b = FaultPlan::seeded(42, 100, 6);
        assert_eq!(a, b);
        assert_eq!(a.events.len(), 6);
        assert!(a.events.iter().all(|e| (1..=100).contains(&e.step)));
        let c = FaultPlan::seeded(43, 100, 6);
        assert_ne!(a, c, "different seeds should give different plans");
    }

    #[test]
    fn seeded_spec_roundtrip() {
        let p = FaultPlan::parse("seeded:7:50:4").unwrap();
        assert_eq!(p, FaultPlan::seeded(7, 50, 4));
    }

    #[test]
    fn events_fire_once() {
        let plan = FaultPlan::parse("alloc@2:5,panic@2").unwrap();
        let mut af = ActiveFaults::new(Some(plan));
        assert!(!af.take_alloc(1, 5), "wrong step must not fire");
        assert!(!af.take_alloc(2, 4), "wrong seq must not fire");
        assert!(af.take_alloc(2, 5));
        assert!(!af.take_alloc(2, 5), "one-shot");
        // Untargeted panic matches the first queried sequence only.
        assert!(af.take_panic(2, 9));
        assert!(!af.take_panic(2, 10));
    }

    #[test]
    fn nan_events_fire_once_per_target() {
        let mut af = ActiveFaults::new(Some(FaultPlan::parse("nan@3:2,nan@5").unwrap()));
        assert!(!af.take_nan(3, 1), "wrong seq must not fire");
        assert!(af.take_nan(3, 2));
        assert!(!af.take_nan(3, 2), "one-shot");
        // Untargeted nan hits the first queried sequence.
        assert!(af.take_nan(5, 7));
        assert!(!af.take_nan(5, 8));
    }

    #[test]
    fn parse_crash_events() {
        let p = FaultPlan::parse("crash@6,crash@9:3").unwrap();
        assert_eq!(
            p.events,
            vec![
                FaultEvent { step: 6, kind: FaultKind::CrashAbort { seq: None } },
                FaultEvent { step: 9, kind: FaultKind::CrashAbort { seq: Some(3) } },
            ]
        );
        assert_eq!(
            FaultPlan::parse("crash@0").unwrap_err(),
            FaultSpecError::BadStep { event: "crash@0".into(), reason: "steps are 1-based, got 0" }
        );
        assert_eq!(
            FaultPlan::parse("crash@4:x").unwrap_err(),
            FaultSpecError::BadSeq { event: "crash@4:x".into() }
        );
    }

    #[test]
    fn crash_events_fire_once_per_target() {
        let mut af = ActiveFaults::new(Some(FaultPlan::parse("crash@4:2,crash@7").unwrap()));
        assert!(!af.take_crash(3, 2), "wrong step must not fire");
        assert!(!af.take_crash(4, 1), "wrong seq must not fire");
        assert!(af.take_crash(4, 2));
        assert!(!af.take_crash(4, 2), "one-shot");
        // Untargeted crash hits the first queried sequence.
        assert!(af.take_crash(7, 9));
        assert!(!af.take_crash(7, 10));
        // Crash events are invisible to the other take_* probes.
        let mut af = ActiveFaults::new(Some(FaultPlan::parse("crash@2").unwrap()));
        assert!(!af.take_panic(2, 1));
        assert!(!af.take_alloc(2, 1));
        assert!(!af.take_nan(2, 1));
        assert!(af.take_crash(2, 1));
    }

    #[test]
    fn slow_steps_fire_once() {
        let mut af = ActiveFaults::new(Some(FaultPlan::parse("slow@3x7").unwrap()));
        assert_eq!(af.take_slow(2), None);
        assert_eq!(af.take_slow(3), Some(7));
        assert_eq!(af.take_slow(3), None);
        assert!(!af.is_empty());
        assert!(ActiveFaults::new(None).is_empty());
    }

    #[test]
    fn stalls_fire_once_for_the_first_caller() {
        let mut af = ActiveFaults::new(Some(FaultPlan::parse("stall@2x9").unwrap()));
        assert_eq!(af.take_stall(1), None);
        assert_eq!(af.take_stall(2), Some(9), "first sequence queried owns the stall");
        assert_eq!(af.take_stall(2), None, "one-shot");
    }
}
