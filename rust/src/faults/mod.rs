//! Deterministic fault injection for chaos testing the engine.
//!
//! A `FaultPlan` is a list of scripted events keyed by the engine's
//! 1-based step counter: allocation failures (surface as KV-cache
//! exhaustion and exercise the preemption path), step panics (exercise
//! per-sequence containment), and slow steps (exercise deadlines).
//! Plans are either written out explicitly (`alloc@5:2,panic@9`) or
//! generated from a seed (`seeded:42:100:6`) via `util::prng`, so a
//! failing chaos run reproduces bit-for-bit from its seed.

use crate::util::prng::SplitMix64;
use anyhow::{anyhow, bail, Result};

/// What to inject. `seq: None` targets whichever sequence is queried
/// first at the scripted step (deterministic: queries follow id order).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Fail the next KV block allocation for the matching sequence.
    AllocFail { seq: Option<u64> },
    /// Panic inside the matching sequence's step body.
    StepPanic { seq: Option<u64> },
    /// Sleep this long before the step runs (deadline pressure).
    SlowStep { ms: u64 },
}

/// One scripted event, armed at a 1-based engine step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultEvent {
    pub step: u64,
    pub kind: FaultKind,
}

/// A deterministic schedule of faults.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// Parse a plan spec.
    ///
    /// Grammar (comma-separated events):
    ///   alloc@STEP[:SEQ]   fail a block allocation at STEP
    ///   panic@STEP[:SEQ]   panic in a sequence's step body at STEP
    ///   slow@STEPxMS       sleep MS milliseconds before STEP
    ///
    /// Or a whole-spec seeded form: `seeded:SEED:HORIZON:COUNT`.
    pub fn parse(spec: &str) -> Result<Self> {
        let spec = spec.trim();
        if spec.is_empty() {
            bail!("empty fault spec");
        }
        if let Some(rest) = spec.strip_prefix("seeded:") {
            let parts: Vec<&str> = rest.split(':').collect();
            if parts.len() != 3 {
                bail!("seeded spec wants seeded:SEED:HORIZON:COUNT, got {spec:?}");
            }
            let seed: u64 = parts[0].parse().map_err(|_| anyhow!("bad seed {:?}", parts[0]))?;
            let horizon: u64 =
                parts[1].parse().map_err(|_| anyhow!("bad horizon {:?}", parts[1]))?;
            let count: usize =
                parts[2].parse().map_err(|_| anyhow!("bad count {:?}", parts[2]))?;
            return Ok(Self::seeded(seed, horizon, count));
        }
        let mut events = Vec::new();
        for ev in spec.split(',') {
            let ev = ev.trim();
            let (kind, rest) = ev
                .split_once('@')
                .ok_or_else(|| anyhow!("fault event {ev:?} missing '@STEP'"))?;
            let parse_step = |s: &str| -> Result<u64> {
                let step: u64 = s.parse().map_err(|_| anyhow!("bad step in {ev:?}"))?;
                if step == 0 {
                    bail!("fault steps are 1-based, got 0 in {ev:?}");
                }
                Ok(step)
            };
            let event = match kind {
                "alloc" | "panic" => {
                    let (step_s, seq) = match rest.split_once(':') {
                        Some((st, sq)) => {
                            let sq: u64 =
                                sq.parse().map_err(|_| anyhow!("bad seq id in {ev:?}"))?;
                            (st, Some(sq))
                        }
                        None => (rest, None),
                    };
                    let step = parse_step(step_s)?;
                    let k = if kind == "alloc" {
                        FaultKind::AllocFail { seq }
                    } else {
                        FaultKind::StepPanic { seq }
                    };
                    FaultEvent { step, kind: k }
                }
                "slow" => {
                    let (step_s, ms_s) = rest
                        .split_once('x')
                        .ok_or_else(|| anyhow!("slow event wants slow@STEPxMS, got {ev:?}"))?;
                    let step = parse_step(step_s)?;
                    let ms: u64 = ms_s.parse().map_err(|_| anyhow!("bad ms in {ev:?}"))?;
                    FaultEvent { step, kind: FaultKind::SlowStep { ms } }
                }
                other => bail!("unknown fault kind {other:?} (want alloc|panic|slow)"),
            };
            events.push(event);
        }
        events.sort_by_key(|e| e.step);
        Ok(Self { events })
    }

    /// Generate `count` faults uniformly over steps [1, horizon] from a
    /// seed. Same seed, same plan — chaos runs are replayable.
    pub fn seeded(seed: u64, horizon: u64, count: usize) -> Self {
        let mut r = SplitMix64::new(seed);
        let mut events = Vec::with_capacity(count);
        for _ in 0..count {
            let step = r.below(horizon.max(1)) + 1;
            let kind = match r.below(3) {
                0 => FaultKind::AllocFail { seq: None },
                1 => FaultKind::StepPanic { seq: None },
                _ => FaultKind::SlowStep { ms: 1 + r.below(5) },
            };
            events.push(FaultEvent { step, kind });
        }
        events.sort_by_key(|e| e.step);
        Self { events }
    }
}

/// Runtime state: the plan plus one-shot fired flags. Owned by the
/// engine; each event fires at most once.
#[derive(Debug, Default)]
pub struct ActiveFaults {
    events: Vec<FaultEvent>,
    fired: Vec<bool>,
}

impl ActiveFaults {
    pub fn new(plan: Option<FaultPlan>) -> Self {
        let events = plan.map(|p| p.events).unwrap_or_default();
        let fired = vec![false; events.len()];
        Self { events, fired }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Consume a slow-step event armed at `step`, returning its delay.
    pub fn take_slow(&mut self, step: u64) -> Option<u64> {
        for (i, ev) in self.events.iter().enumerate() {
            if self.fired[i] || ev.step != step {
                continue;
            }
            if let FaultKind::SlowStep { ms } = ev.kind {
                self.fired[i] = true;
                return Some(ms);
            }
        }
        None
    }

    /// Consume an allocation-failure event armed at `step` targeting
    /// `seq` (untargeted events match the first sequence queried).
    pub fn take_alloc(&mut self, step: u64, seq: u64) -> bool {
        self.take_targeted(step, seq, true)
    }

    /// Consume a panic event armed at `step` targeting `seq`.
    pub fn take_panic(&mut self, step: u64, seq: u64) -> bool {
        self.take_targeted(step, seq, false)
    }

    fn take_targeted(&mut self, step: u64, seq: u64, alloc: bool) -> bool {
        for (i, ev) in self.events.iter().enumerate() {
            if self.fired[i] || ev.step != step {
                continue;
            }
            let target = match ev.kind {
                FaultKind::AllocFail { seq } if alloc => seq,
                FaultKind::StepPanic { seq } if !alloc => seq,
                _ => continue,
            };
            let hit = match target {
                Some(t) => t == seq,
                None => true,
            };
            if hit {
                self.fired[i] = true;
                return true;
            }
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_explicit_events() {
        let p = FaultPlan::parse("alloc@5:2, panic@9, slow@12x50").unwrap();
        assert_eq!(
            p.events,
            vec![
                FaultEvent { step: 5, kind: FaultKind::AllocFail { seq: Some(2) } },
                FaultEvent { step: 9, kind: FaultKind::StepPanic { seq: None } },
                FaultEvent { step: 12, kind: FaultKind::SlowStep { ms: 50 } },
            ]
        );
    }

    #[test]
    fn parse_sorts_by_step() {
        let p = FaultPlan::parse("panic@9,alloc@3").unwrap();
        assert_eq!(p.events[0].step, 3);
        assert_eq!(p.events[1].step, 9);
    }

    #[test]
    fn parse_rejects_bad_specs() {
        for bad in ["", "alloc", "alloc@0", "alloc@x", "boom@3", "slow@5", "slow@5x", "seeded:1:2"]
        {
            assert!(FaultPlan::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn seeded_is_deterministic_and_in_range() {
        let a = FaultPlan::seeded(42, 100, 6);
        let b = FaultPlan::seeded(42, 100, 6);
        assert_eq!(a, b);
        assert_eq!(a.events.len(), 6);
        assert!(a.events.iter().all(|e| (1..=100).contains(&e.step)));
        let c = FaultPlan::seeded(43, 100, 6);
        assert_ne!(a, c, "different seeds should give different plans");
    }

    #[test]
    fn seeded_spec_roundtrip() {
        let p = FaultPlan::parse("seeded:7:50:4").unwrap();
        assert_eq!(p, FaultPlan::seeded(7, 50, 4));
    }

    #[test]
    fn events_fire_once() {
        let plan = FaultPlan::parse("alloc@2:5,panic@2").unwrap();
        let mut af = ActiveFaults::new(Some(plan));
        assert!(!af.take_alloc(1, 5), "wrong step must not fire");
        assert!(!af.take_alloc(2, 4), "wrong seq must not fire");
        assert!(af.take_alloc(2, 5));
        assert!(!af.take_alloc(2, 5), "one-shot");
        // Untargeted panic matches the first queried sequence only.
        assert!(af.take_panic(2, 9));
        assert!(!af.take_panic(2, 10));
    }

    #[test]
    fn slow_steps_fire_once() {
        let mut af = ActiveFaults::new(Some(FaultPlan::parse("slow@3x7").unwrap()));
        assert_eq!(af.take_slow(2), None);
        assert_eq!(af.take_slow(3), Some(7));
        assert_eq!(af.take_slow(3), None);
        assert!(!af.is_empty());
        assert!(ActiveFaults::new(None).is_empty());
    }
}
