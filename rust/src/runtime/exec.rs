//! Typed execution of decode/prefill artifacts.
//!
//! Argument order per artifact (the L2<->L3 ABI, DESIGN.md §8):
//!   [weights..., omega, tokens, pos, K, V, mask]          (decode)
//!   [weights..., omega, tokens, pos0, pastK, pastV, mask] (prefill)
//! Weights/omega are persistent device buffers; the per-call inputs are
//! uploaded here. Outputs come back as one tuple literal and are
//! unpacked into flat `Vec<f32>`s with documented layouts.

use super::artifacts::ArtifactMeta;
use super::Runtime;
use anyhow::{anyhow, Result};
use xla::PjRtBuffer;

/// Decode outputs. Layouts (row-major):
/// logits [B, V]; k_new/v_new [B, L, H, dh]; feat_new [B, L, H, n];
/// probs [B, L, H, S+1] (slot S = the just-written self token).
pub struct DecodeOut {
    pub logits: Vec<f32>,
    pub k_new: Vec<f32>,
    pub v_new: Vec<f32>,
    pub feat_new: Vec<f32>,
    pub probs: Vec<f32>,
    pub bucket_s: usize,
    pub bucket_b: usize,
}

/// Per-layer qkv outputs. Layouts: q/k/v [B, H, dh] (post-RoPE);
/// phi_q/phi_k [B, H, n].
pub struct QkvOut {
    pub q: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub phi_q: Vec<f32>,
    pub phi_k: Vec<f32>,
}

/// Per-layer attend+mlp outputs: x_out [B, d]; probs [B, H, S+1].
pub struct AttnMlpOut {
    pub x: Vec<f32>,
    pub probs: Vec<f32>,
}

/// Prefill outputs. Layouts: logits [T, V]; k_c/v_c [L, H, T, dh];
/// feat_c [L, H, T, n]; colsum [L, H, P+T].
pub struct PrefillOut {
    pub logits: Vec<f32>,
    pub k_c: Vec<f32>,
    pub v_c: Vec<f32>,
    pub feat_c: Vec<f32>,
    pub colsum: Vec<f32>,
    pub bucket_p: usize,
}

impl Runtime {
    /// Execute one decode step. Input slices must already be padded to
    /// the artifact's (B, S) bucket:
    /// tokens/pos len B; k/v [B,L,H,S,dh]; mask [B,S].
    pub fn decode(
        &self,
        meta: &ArtifactMeta,
        omega: &PjRtBuffer,
        tokens: &[i32],
        pos: &[i32],
        k: &[f32],
        v: &[f32],
        mask: &[f32],
    ) -> Result<DecodeOut> {
        let cfg = &self.config;
        let (b, s) = (meta.batch, meta.len);
        let (l, h, dh, nf) = (cfg.n_layers, cfg.n_heads, cfg.d_head, meta.n_feat);
        debug_assert_eq!(tokens.len(), b);
        debug_assert_eq!(k.len(), b * l * h * s * dh);
        debug_assert_eq!(mask.len(), b * l * h * s, "mask is per (layer, head)");

        let c = &self.client;
        let up = |data: &[f32], dims: &[usize]| -> Result<PjRtBuffer> {
            c.buffer_from_host_buffer(data, dims, None)
                .map_err(|e| anyhow!("upload: {e}"))
        };
        let tok_b = c
            .buffer_from_host_buffer(tokens, &[b], None)
            .map_err(|e| anyhow!("upload tokens: {e}"))?;
        let pos_b = c
            .buffer_from_host_buffer(pos, &[b], None)
            .map_err(|e| anyhow!("upload pos: {e}"))?;
        let k_b = up(k, &[b, l, h, s, dh])?;
        let v_b = up(v, &[b, l, h, s, dh])?;
        let m_b = up(mask, &[b, l, h, s])?;

        let mut args: Vec<&PjRtBuffer> = self.weights.buffers().iter().collect();
        args.push(omega);
        args.push(&tok_b);
        args.push(&pos_b);
        args.push(&k_b);
        args.push(&v_b);
        args.push(&m_b);

        let exe = self.executable(&meta.name)?;
        let result = exe
            .execute_b(&args)
            .map_err(|e| anyhow!("execute {}: {e}", meta.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e}"))?;
        let mut parts = tuple
            .to_tuple()
            .map_err(|e| anyhow!("untuple: {e}"))?;
        if parts.len() != 5 {
            return Err(anyhow!("decode returned {} outputs, want 5", parts.len()));
        }
        let probs = parts.pop().unwrap().to_vec::<f32>().map_err(|e| anyhow!("{e}"))?;
        let feat_new = parts.pop().unwrap().to_vec::<f32>().map_err(|e| anyhow!("{e}"))?;
        let v_new = parts.pop().unwrap().to_vec::<f32>().map_err(|e| anyhow!("{e}"))?;
        let k_new = parts.pop().unwrap().to_vec::<f32>().map_err(|e| anyhow!("{e}"))?;
        let logits = parts.pop().unwrap().to_vec::<f32>().map_err(|e| anyhow!("{e}"))?;
        debug_assert_eq!(logits.len(), b * cfg.vocab);
        debug_assert_eq!(feat_new.len(), b * l * h * nf);
        debug_assert_eq!(probs.len(), b * l * h * (s + 1));
        Ok(DecodeOut { logits, k_new, v_new, feat_new, probs, bucket_s: s, bucket_b: b })
    }

    /// Per-layer QKV projection (+ phi features) — the first half of the
    /// Radar per-layer pipeline. x: [B, d]; pos: [B].
    pub fn qkv(
        &self,
        meta: &ArtifactMeta,
        layer: usize,
        omega: &PjRtBuffer,
        x: &[f32],
        pos: &[i32],
    ) -> Result<QkvOut> {
        let cfg = &self.config;
        let b = meta.batch;
        debug_assert_eq!(x.len(), b * cfg.d_model);
        let c = &self.client;
        let x_b = c
            .buffer_from_host_buffer(x, &[b, cfg.d_model], None)
            .map_err(|e| anyhow!("upload x: {e}"))?;
        let pos_b = c
            .buffer_from_host_buffer(pos, &[b], None)
            .map_err(|e| anyhow!("upload pos: {e}"))?;
        let w = |suffix: &str| -> Result<&PjRtBuffer> {
            let name = format!("layers.{layer}.{suffix}");
            self.weights
                .buffer(&name)
                .ok_or_else(|| anyhow!("missing weight {name}"))
        };
        let args: Vec<&PjRtBuffer> =
            vec![w("wq")?, w("wk")?, w("wv")?, w("ln1")?, omega, &x_b, &pos_b];
        let exe = self.executable(&meta.name)?;
        let result = exe
            .execute_b(&args)
            .map_err(|e| anyhow!("execute {}: {e}", meta.name))?;
        let tuple = result[0][0].to_literal_sync().map_err(|e| anyhow!("{e}"))?;
        let mut parts = tuple.to_tuple().map_err(|e| anyhow!("untuple: {e}"))?;
        if parts.len() != 5 {
            return Err(anyhow!("qkv returned {} outputs, want 5", parts.len()));
        }
        let phi_k = parts.pop().unwrap().to_vec::<f32>().map_err(|e| anyhow!("{e}"))?;
        let phi_q = parts.pop().unwrap().to_vec::<f32>().map_err(|e| anyhow!("{e}"))?;
        let v = parts.pop().unwrap().to_vec::<f32>().map_err(|e| anyhow!("{e}"))?;
        let k = parts.pop().unwrap().to_vec::<f32>().map_err(|e| anyhow!("{e}"))?;
        let q = parts.pop().unwrap().to_vec::<f32>().map_err(|e| anyhow!("{e}"))?;
        Ok(QkvOut { q, k, v, phi_q, phi_k })
    }

    /// Per-layer attention + MLP over the gathered KV — the second half
    /// of the Radar pipeline. K/V: [B,H,S,dh]; mask: [B,H,S].
    #[allow(clippy::too_many_arguments)]
    pub fn attn_mlp(
        &self,
        meta: &ArtifactMeta,
        layer: usize,
        x: &[f32],
        q: &[f32],
        k: &[f32],
        v: &[f32],
        gk: &[f32],
        gv: &[f32],
        mask: &[f32],
    ) -> Result<AttnMlpOut> {
        let cfg = &self.config;
        let (b, s) = (meta.batch, meta.len);
        let (h, dh) = (cfg.n_heads, cfg.d_head);
        debug_assert_eq!(gk.len(), b * h * s * dh);
        debug_assert_eq!(mask.len(), b * h * s);
        let c = &self.client;
        let up = |data: &[f32], dims: &[usize]| -> Result<PjRtBuffer> {
            c.buffer_from_host_buffer(data, dims, None)
                .map_err(|e| anyhow!("upload: {e}"))
        };
        let x_b = up(x, &[b, cfg.d_model])?;
        let q_b = up(q, &[b, h, dh])?;
        let k_b = up(k, &[b, h, dh])?;
        let v_b = up(v, &[b, h, dh])?;
        let gk_b = up(gk, &[b, h, s, dh])?;
        let gv_b = up(gv, &[b, h, s, dh])?;
        let m_b = up(mask, &[b, h, s])?;
        let w = |suffix: &str| -> Result<&PjRtBuffer> {
            let name = format!("layers.{layer}.{suffix}");
            self.weights
                .buffer(&name)
                .ok_or_else(|| anyhow!("missing weight {name}"))
        };
        let args: Vec<&PjRtBuffer> = vec![
            w("wo")?, w("w1")?, w("w2")?, w("ln2")?,
            &x_b, &q_b, &k_b, &v_b, &gk_b, &gv_b, &m_b,
        ];
        let exe = self.executable(&meta.name)?;
        let result = exe
            .execute_b(&args)
            .map_err(|e| anyhow!("execute {}: {e}", meta.name))?;
        let tuple = result[0][0].to_literal_sync().map_err(|e| anyhow!("{e}"))?;
        let mut parts = tuple.to_tuple().map_err(|e| anyhow!("untuple: {e}"))?;
        if parts.len() != 2 {
            return Err(anyhow!("attn_mlp returned {} outputs, want 2", parts.len()));
        }
        let probs = parts.pop().unwrap().to_vec::<f32>().map_err(|e| anyhow!("{e}"))?;
        let x_out = parts.pop().unwrap().to_vec::<f32>().map_err(|e| anyhow!("{e}"))?;
        Ok(AttnMlpOut { x: x_out, probs })
    }

    /// Execute one prefill chunk. tokens len T; past k/v [L,H,P,dh];
    /// past_mask [P] — all padded to the artifact's P bucket.
    pub fn prefill(
        &self,
        meta: &ArtifactMeta,
        omega: &PjRtBuffer,
        tokens: &[i32],
        pos0: i32,
        past_k: &[f32],
        past_v: &[f32],
        past_mask: &[f32],
    ) -> Result<PrefillOut> {
        let cfg = &self.config;
        let (t, p) = (meta.chunk, meta.len);
        let (l, h, dh) = (cfg.n_layers, cfg.n_heads, cfg.d_head);
        debug_assert_eq!(tokens.len(), t);
        debug_assert_eq!(past_k.len(), l * h * p * dh);
        debug_assert_eq!(past_mask.len(), p);

        let c = &self.client;
        let tok_b = c
            .buffer_from_host_buffer(tokens, &[t], None)
            .map_err(|e| anyhow!("upload tokens: {e}"))?;
        let pos_b = c
            .buffer_from_host_buffer(&[pos0], &[], None)
            .map_err(|e| anyhow!("upload pos0: {e}"))?;
        // P=0: jax drops the zero-sized pastK/pastV/mask parameters
        // during lowering, so the compiled program doesn't take them.
        let past_bufs = if p > 0 {
            let k_b = c
                .buffer_from_host_buffer(past_k, &[l, h, p, dh], None)
                .map_err(|e| anyhow!("upload pastK: {e}"))?;
            let v_b = c
                .buffer_from_host_buffer(past_v, &[l, h, p, dh], None)
                .map_err(|e| anyhow!("upload pastV: {e}"))?;
            let m_b = c
                .buffer_from_host_buffer(past_mask, &[p], None)
                .map_err(|e| anyhow!("upload mask: {e}"))?;
            Some((k_b, v_b, m_b))
        } else {
            None
        };

        let mut args: Vec<&PjRtBuffer> = self.weights.buffers().iter().collect();
        args.push(omega);
        args.push(&tok_b);
        args.push(&pos_b);
        if let Some((k_b, v_b, m_b)) = &past_bufs {
            args.push(k_b);
            args.push(v_b);
            args.push(m_b);
        }

        let exe = self.executable(&meta.name)?;
        let result = exe
            .execute_b(&args)
            .map_err(|e| anyhow!("execute {}: {e}", meta.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e}"))?;
        let mut parts = tuple.to_tuple().map_err(|e| anyhow!("untuple: {e}"))?;
        if parts.len() != 5 {
            return Err(anyhow!("prefill returned {} outputs, want 5", parts.len()));
        }
        let colsum = parts.pop().unwrap().to_vec::<f32>().map_err(|e| anyhow!("{e}"))?;
        let feat_c = parts.pop().unwrap().to_vec::<f32>().map_err(|e| anyhow!("{e}"))?;
        let v_c = parts.pop().unwrap().to_vec::<f32>().map_err(|e| anyhow!("{e}"))?;
        let k_c = parts.pop().unwrap().to_vec::<f32>().map_err(|e| anyhow!("{e}"))?;
        let logits = parts.pop().unwrap().to_vec::<f32>().map_err(|e| anyhow!("{e}"))?;
        debug_assert_eq!(logits.len(), t * cfg.vocab);
        debug_assert_eq!(colsum.len(), l * h * (p + t));
        Ok(PrefillOut { logits, k_c, v_c, feat_c, colsum, bucket_p: p })
    }
}
