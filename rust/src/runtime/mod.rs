//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! Python is never involved here — this module plus the artifact files
//! are the entire model runtime. Weights and Omega are uploaded to the
//! device **once** at startup (`buffer_from_host_buffer`) and passed by
//! reference on every call (`execute_b`), so the per-token hot path
//! copies only the gathered KV buffers.

mod artifacts;
mod exec;
mod weights;

pub use artifacts::{ArtifactKind, ArtifactMeta, Registry};
pub use exec::{AttnMlpOut, DecodeOut, PrefillOut, QkvOut};
pub use weights::WeightSet;

use crate::config::{ArtifactPaths, ModelConfig};
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use xla::{PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

/// One loaded model: client + device-resident weights + executable cache.
pub struct Runtime {
    pub client: PjRtClient,
    pub config: ModelConfig,
    pub paths: ArtifactPaths,
    pub registry: Registry,
    pub weights: WeightSet,
    omegas: Mutex<HashMap<usize, Arc<PjRtBuffer>>>,
    executables: Mutex<HashMap<String, Arc<PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Load manifest + weights and upload them to the device.
    pub fn load(paths: ArtifactPaths) -> Result<Self> {
        let manifest = paths.load_manifest()?;
        let config = ModelConfig::from_json(
            manifest
                .get("config")
                .ok_or_else(|| anyhow!("manifest missing config"))?,
        )?;
        let registry = Registry::from_manifest(&manifest)?;
        let client = PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e}"))?;
        let weights = WeightSet::load(&client, &paths, &manifest)?;
        crate::info!(
            "runtime up: model={} platform={} artifacts={} tensors={}",
            config.name,
            client.platform_name(),
            registry.len(),
            weights.n_tensors(),
        );
        Ok(Self {
            client,
            config,
            paths,
            registry,
            weights,
            omegas: Mutex::new(HashMap::new()),
            executables: Mutex::new(HashMap::new()),
        })
    }

    /// Device-resident Omega for feature dimension n (uploaded once).
    pub fn omega(&self, n: usize) -> Result<Arc<PjRtBuffer>> {
        if let Some(o) = self.omegas.lock().unwrap().get(&n) {
            return Ok(o.clone());
        }
        let npz = xla::Literal::read_npz(self.paths.omega(n), &())
            .map_err(|e| anyhow!("read {:?}: {e}", self.paths.omega(n)))?;
        let (_, lit) = npz
            .into_iter()
            .find(|(k, _)| k.starts_with("omega"))
            .ok_or_else(|| anyhow!("omega npz missing 'omega' entry"))?;
        let data = lit.to_vec::<f32>().map_err(|e| anyhow!("omega data: {e}"))?;
        let buf = self
            .client
            .buffer_from_host_buffer(&data, &[n, self.config.d_head], None)
            .map_err(|e| anyhow!("upload omega: {e}"))?;
        let arc = Arc::new(buf);
        self.omegas.lock().unwrap().insert(n, arc.clone());
        Ok(arc)
    }

    /// Compile (or fetch cached) an artifact by name.
    pub fn executable(&self, name: &str) -> Result<Arc<PjRtLoadedExecutable>> {
        if let Some(e) = self.executables.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let path = self.paths.hlo(name);
        let t = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parse {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e}"))?;
        crate::debug!("compiled {name} in {:.2}s", t.elapsed().as_secs_f64());
        let arc = Arc::new(exe);
        self.executables.lock().unwrap().insert(name.to_string(), arc.clone());
        Ok(arc)
    }

    /// Pre-compile a set of artifacts (server warmup).
    pub fn warmup(&self, names: &[String]) -> Result<()> {
        for n in names {
            self.executable(n).with_context(|| format!("warming {n}"))?;
        }
        Ok(())
    }

    pub fn compiled_count(&self) -> usize {
        self.executables.lock().unwrap().len()
    }
}

use xla::FromRawBytes as _;
