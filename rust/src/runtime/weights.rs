//! Weight loading: `weights.npz` + the manifest tensor ABI -> one
//! device-resident `PjRtBuffer` per tensor, uploaded once at startup.

use crate::config::ArtifactPaths;
use crate::util::json::Json;
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use xla::{FromRawBytes, Literal, PjRtBuffer, PjRtClient};

pub struct WeightSet {
    /// Tensors in manifest order (the artifact parameter order).
    buffers: Vec<PjRtBuffer>,
    names: Vec<String>,
    shapes: Vec<Vec<usize>>,
    /// Host copies kept for rust-side math (exact-score ablation etc.).
    host: HashMap<String, (Vec<usize>, Vec<f32>)>,
}

impl WeightSet {
    pub fn load(client: &PjRtClient, paths: &ArtifactPaths, manifest: &Json) -> Result<Self> {
        let tensor_specs = manifest
            .get("tensors")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing tensors"))?;

        // Read the npz once; reorder into manifest order.
        let npz = Literal::read_npz(paths.weights(), &())
            .map_err(|e| anyhow!("read {:?}: {e}", paths.weights()))?;
        let mut by_name: HashMap<String, Literal> = npz
            .into_iter()
            .map(|(name, lit)| (name.trim_end_matches(".npy").to_string(), lit))
            .collect();

        let mut buffers = Vec::new();
        let mut names = Vec::new();
        let mut shapes = Vec::new();
        let mut host = HashMap::new();
        for spec in tensor_specs {
            let name = spec
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("tensor spec missing name"))?;
            let shape: Vec<usize> = spec
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("tensor spec missing shape"))?
                .iter()
                .filter_map(Json::as_usize)
                .collect();
            let lit = by_name
                .remove(name)
                .ok_or_else(|| anyhow!("weights.npz missing tensor '{name}'"))?;
            let want: usize = shape.iter().product();
            if lit.element_count() != want {
                return Err(anyhow!(
                    "tensor '{name}': npz has {} elements, manifest wants {want}",
                    lit.element_count()
                ));
            }
            let data = lit.to_vec::<f32>().map_err(|e| anyhow!("tensor '{name}': {e}"))?;
            let buf = client
                .buffer_from_host_buffer(&data, &shape, None)
                .map_err(|e| anyhow!("upload '{name}': {e}"))?;
            buffers.push(buf);
            names.push(name.to_string());
            shapes.push(shape.clone());
            host.insert(name.to_string(), (shape, data));
        }

        Ok(Self { buffers, names, shapes, host })
    }

    pub fn buffers(&self) -> &[PjRtBuffer] {
        &self.buffers
    }

    pub fn n_tensors(&self) -> usize {
        self.buffers.len()
    }

    pub fn names(&self) -> &[String] {
        &self.names
    }

    pub fn buffer(&self, name: &str) -> Option<&PjRtBuffer> {
        self.names.iter().position(|n| n == name).map(|i| &self.buffers[i])
    }

    pub fn shape(&self, name: &str) -> Option<&[usize]> {
        self.names
            .iter()
            .position(|n| n == name)
            .map(|i| self.shapes[i].as_slice())
    }

    /// Host copy of a tensor (for rust-side math / debugging).
    pub fn host_tensor(&self, name: &str) -> Option<(&[usize], &[f32])> {
        self.host.get(name).map(|(s, d)| (s.as_slice(), d.as_slice()))
    }
}
