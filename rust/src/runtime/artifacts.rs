//! Artifact registry: what was AOT-compiled, and bucket resolution.
//!
//! The scheduler asks "I have a batch of b rows each needing s selected
//! tokens" and the registry answers with the smallest compiled
//! `decode_b{B}_s{S}` artifact with B >= b and S >= s (mask padding
//! absorbs the slack) — the same shape-bucketing trick vLLM uses for
//! cudagraphs.

use crate::util::json::Json;
use anyhow::{anyhow, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    /// Fused all-layer decode step (one dispatch/token; policies whose
    /// selection does not depend on the current query).
    Decode,
    /// Chunked prefill.
    Prefill,
    /// Per-layer QKV projection + phi features (Radar pipeline, 1/2).
    Qkv,
    /// Per-layer attention-over-gather + MLP (Radar pipeline, 2/2).
    AttnMlp,
}

#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub kind: ArtifactKind,
    /// Decode: batch bucket. Prefill: unused (1).
    pub batch: usize,
    /// Decode: selected-KV bucket S. Prefill: past bucket P.
    pub len: usize,
    /// Prefill chunk length T (prefill only).
    pub chunk: usize,
    /// Random-feature dimension baked into this artifact's phi output.
    pub n_feat: usize,
}

#[derive(Debug, Clone)]
pub struct Registry {
    artifacts: Vec<ArtifactMeta>,
    pub prefill_chunk: usize,
}

impl Registry {
    pub fn from_manifest(manifest: &Json) -> Result<Self> {
        let list = manifest
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?;
        let mut artifacts = Vec::new();
        for a in list {
            let name = a
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact missing name"))?
                .to_string();
            let kind = match a.get("kind").and_then(Json::as_str) {
                Some("decode") => ArtifactKind::Decode,
                Some("prefill") => ArtifactKind::Prefill,
                Some("qkv") => ArtifactKind::Qkv,
                Some("attn_mlp") => ArtifactKind::AttnMlp,
                k => return Err(anyhow!("artifact {name}: bad kind {k:?}")),
            };
            let g = |k: &str| a.get(k).and_then(Json::as_usize).unwrap_or(0);
            let len = match kind {
                ArtifactKind::Decode | ArtifactKind::AttnMlp => g("S"),
                ArtifactKind::Prefill => g("P"),
                ArtifactKind::Qkv => 0,
            };
            artifacts.push(ArtifactMeta {
                name,
                kind,
                batch: g("B").max(1),
                len,
                chunk: g("T"),
                n_feat: g("n"),
            });
        }
        let prefill_chunk = manifest
            .get("prefill_chunk")
            .and_then(Json::as_usize)
            .unwrap_or(128);
        Ok(Self { artifacts, prefill_chunk })
    }

    pub fn len(&self) -> usize {
        self.artifacts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.artifacts.is_empty()
    }

    pub fn all(&self) -> &[ArtifactMeta] {
        &self.artifacts
    }

    /// Smallest decode bucket with batch >= b, len >= s, n_feat == n.
    pub fn resolve_decode(&self, b: usize, s: usize, n: usize) -> Result<&ArtifactMeta> {
        self.artifacts
            .iter()
            .filter(|a| {
                a.kind == ArtifactKind::Decode
                    && a.batch >= b
                    && a.len >= s
                    && a.n_feat == n
            })
            .min_by_key(|a| (a.len, a.batch))
            .ok_or_else(|| {
                anyhow!("no decode artifact for b={b} s={s} n={n} (largest compiled: {:?})",
                    self.max_decode_s(n))
            })
    }

    /// Smallest prefill bucket with past P >= p, n_feat == n.
    pub fn resolve_prefill(&self, p: usize, n: usize) -> Result<&ArtifactMeta> {
        self.artifacts
            .iter()
            .filter(|a| a.kind == ArtifactKind::Prefill && a.len >= p && a.n_feat == n)
            .min_by_key(|a| a.len)
            .ok_or_else(|| anyhow!("no prefill artifact for p={p} n={n}"))
    }

    /// Exact-batch qkv artifact for the per-layer pipeline.
    pub fn resolve_qkv(&self, b: usize, n: usize) -> Result<&ArtifactMeta> {
        self.artifacts
            .iter()
            .filter(|a| a.kind == ArtifactKind::Qkv && a.batch >= b && a.n_feat == n)
            .min_by_key(|a| a.batch)
            .ok_or_else(|| anyhow!("no qkv artifact for b={b} n={n}"))
    }

    /// Smallest attn_mlp bucket with batch >= b, len >= s.
    pub fn resolve_attn_mlp(&self, b: usize, s: usize) -> Result<&ArtifactMeta> {
        self.artifacts
            .iter()
            .filter(|a| a.kind == ArtifactKind::AttnMlp && a.batch >= b && a.len >= s)
            .min_by_key(|a| (a.len, a.batch))
            .ok_or_else(|| anyhow!("no attn_mlp artifact for b={b} s={s}"))
    }

    /// Largest compiled decode S for a given n (vanilla's context cap).
    pub fn max_decode_s(&self, n: usize) -> Option<usize> {
        self.artifacts
            .iter()
            .filter(|a| a.kind == ArtifactKind::Decode && a.n_feat == n)
            .map(|a| a.len)
            .max()
    }

    pub fn max_batch(&self, n: usize) -> usize {
        self.artifacts
            .iter()
            .filter(|a| a.kind == ArtifactKind::Decode && a.n_feat == n)
            .map(|a| a.batch)
            .max()
            .unwrap_or(1)
    }

    pub fn decode_names(&self, n: usize) -> Vec<String> {
        self.artifacts
            .iter()
            .filter(|a| a.kind == ArtifactKind::Decode && a.n_feat == n)
            .map(|a| a.name.clone())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn registry() -> Registry {
        let manifest = Json::parse(
            r#"{"prefill_chunk":128,"artifacts":[
                {"name":"decode_b1_s128_n128","kind":"decode","B":1,"S":128,"n":128},
                {"name":"decode_b1_s256_n128","kind":"decode","B":1,"S":256,"n":128},
                {"name":"decode_b4_s256_n128","kind":"decode","B":4,"S":256,"n":128},
                {"name":"decode_b1_s256_n64","kind":"decode","B":1,"S":256,"n":64},
                {"name":"prefill_t128_p0_n128","kind":"prefill","T":128,"P":0,"n":128},
                {"name":"prefill_t128_p256_n128","kind":"prefill","T":128,"P":256,"n":128}
            ]}"#,
        )
        .unwrap();
        Registry::from_manifest(&manifest).unwrap()
    }

    #[test]
    fn resolves_smallest_fitting_decode() {
        let r = registry();
        assert_eq!(r.resolve_decode(1, 100, 128).unwrap().name, "decode_b1_s128_n128");
        assert_eq!(r.resolve_decode(1, 129, 128).unwrap().name, "decode_b1_s256_n128");
        assert_eq!(r.resolve_decode(2, 100, 128).unwrap().name, "decode_b4_s256_n128");
        assert_eq!(r.resolve_decode(1, 200, 64).unwrap().name, "decode_b1_s256_n64");
    }

    #[test]
    fn resolve_failure_is_error() {
        let r = registry();
        assert!(r.resolve_decode(8, 128, 128).is_err());
        assert!(r.resolve_decode(1, 512, 128).is_err());
        assert!(r.resolve_decode(1, 128, 999).is_err());
    }

    #[test]
    fn resolves_prefill() {
        let r = registry();
        assert_eq!(r.resolve_prefill(0, 128).unwrap().name, "prefill_t128_p0_n128");
        assert_eq!(r.resolve_prefill(1, 128).unwrap().name, "prefill_t128_p256_n128");
        assert!(r.resolve_prefill(300, 128).is_err());
    }

    #[test]
    fn max_decode_s() {
        let r = registry();
        assert_eq!(r.max_decode_s(128), Some(256));
        assert_eq!(r.max_batch(128), 4);
    }
}
