//! radar-serve: a rust + JAX + Pallas serving framework reproducing
//! "Radar: Fast Long-Context Decoding for Any Transformer" (ICLR 2025).
//!
//! Layering (DESIGN.md):
//! - L1/L2 live in `python/compile/` and run once at `make artifacts`;
//! - this crate is L3: the serving coordinator that loads the HLO-text
//!   artifacts via PJRT and owns the entire request path.

pub mod config;
pub mod engine;
pub mod faults;
pub mod harness;
pub mod kvcache;
pub mod metrics;
pub mod model;
pub mod policy;
pub mod prefix;
pub mod radar;
pub mod recovery;
pub mod runtime;
pub mod server;
pub mod util;
pub mod workload;
