//! Durable session journal + deterministic crash recovery.
//!
//! Long-context serving state is expensive to lose — hours of
//! accumulated KV blocks and Radar segment summaries — but cheap to
//! *re-derive* as long as three things survive a crash: each admitted
//! request (prompt + resolved sampler parameters), the tokens sampled
//! so far, and how each session ended. This module persists exactly
//! that:
//!
//!   - an append-only binary **journal** of checksummed frames
//!     (`[u32 len][u32 crc32][payload]`, little-endian). ADMIT records
//!     carry the full `GenRequest` with the sampler seed, temperature,
//!     and greedy flag *resolved at admission* (so recovery is immune
//!     to `ServingConfig` drift across restarts); STEP records carry
//!     sampled token ids; FINISH records the terminal reason. Appends
//!     are fsync-batched (`fsync_every` frames per `sync_data`), so a
//!     hard abort can lose the unsynced tail — but sampling is
//!     deterministic, so lost-tail tokens are *regenerated
//!     identically* on recovery rather than gone.
//!   - periodic **checkpoints** (atomic write-temp-then-rename via
//!     [`crate::util::fsio::write_atomic`]) that snapshot the session
//!     mirror plus the prefix-index topology and rotate the journal to
//!     a fresh epoch, bounding replay to one journal segment.
//!
//! On [`Journal::open`], the checkpoint (if present and valid) seeds
//! an in-memory [`SessionMirror`]; the current epoch's journal is then
//! scanned frame-by-frame. A torn or corrupt tail frame truncates the
//! file at the last valid boundary — never a fatal error. The engine
//! re-admits every unfinished session through the preemption-resume
//! path (re-prefilling warm via the prefix cache) after
//! fast-forwarding its sampler past the journaled tokens, so the
//! remaining token stream is byte-identical to an uncrashed run. The
//! server reads the same mirror to answer `GET /v1/sessions/{id}` and
//! to replay SSE frames from a client's `Last-Event-ID`.

use crate::engine::{FinishReason, GenRequest, Priority};
use crate::metrics::Metrics;
use anyhow::{Context, Result};
use std::collections::{BTreeMap, VecDeque};
use std::fs::{self, File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

const CHECKPOINT_FILE: &str = "checkpoint.bin";
/// Checkpoint payload magic ("RjC1" LE) — rejects stray files early.
const CKPT_MAGIC: u32 = 0x3143_6a52;
/// Finished sessions retained in the mirror for stream resume; older
/// ones are evicted FIFO (their journal records rotate away at the
/// next checkpoint anyway).
const MAX_FINISHED_RETAINED: usize = 256;

const TAG_ADMIT: u8 = 1;
const TAG_STEP: u8 = 2;
const TAG_FINISH: u8 = 3;

// ---------------------------------------------------------------------
// CRC32 (IEEE 802.3 polynomial, bitwise — no table to keep it obvious)
// ---------------------------------------------------------------------

pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in bytes {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

// ---------------------------------------------------------------------
// Binary encoding helpers
// ---------------------------------------------------------------------

fn put_u8(b: &mut Vec<u8>, v: u8) {
    b.push(v);
}

fn put_u32(b: &mut Vec<u8>, v: u32) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(b: &mut Vec<u8>, v: u64) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_i32(b: &mut Vec<u8>, v: i32) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(b: &mut Vec<u8>, v: f32) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(b: &mut Vec<u8>, v: f64) {
    b.extend_from_slice(&v.to_le_bytes());
}

/// Cursor over a byte slice; every getter returns `None` on underrun so
/// a truncated/corrupt payload decodes to `None`, never a panic.
struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(b: &'a [u8]) -> Self {
        Self { b, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        if self.b.len() - self.pos < n {
            return None;
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Some(s)
    }

    fn u8(&mut self) -> Option<u8> {
        self.take(1).map(|s| s[0])
    }

    fn u32(&mut self) -> Option<u32> {
        self.take(4).map(|s| u32::from_le_bytes(s.try_into().unwrap()))
    }

    fn u64(&mut self) -> Option<u64> {
        self.take(8).map(|s| u64::from_le_bytes(s.try_into().unwrap()))
    }

    fn i32(&mut self) -> Option<i32> {
        self.take(4).map(|s| i32::from_le_bytes(s.try_into().unwrap()))
    }

    fn f32(&mut self) -> Option<f32> {
        self.take(4).map(|s| f32::from_le_bytes(s.try_into().unwrap()))
    }

    fn f64(&mut self) -> Option<f64> {
        self.take(8).map(|s| f64::from_le_bytes(s.try_into().unwrap()))
    }

    fn done(&self) -> bool {
        self.pos == self.b.len()
    }
}

fn priority_code(p: Priority) -> u8 {
    match p {
        Priority::Batch => 0,
        Priority::Normal => 1,
        Priority::High => 2,
    }
}

fn priority_from_code(c: u8) -> Option<Priority> {
    match c {
        0 => Some(Priority::Batch),
        1 => Some(Priority::Normal),
        2 => Some(Priority::High),
        _ => None,
    }
}

// ---------------------------------------------------------------------
// Records
// ---------------------------------------------------------------------

/// Why a journaled session reached its terminal record. Mirrors
/// [`FinishReason`] plus `Error` (failures are terminal too — a
/// recovered engine must not re-decode a request that already failed).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Terminal {
    Length,
    Stop,
    Cancelled,
    Timeout,
    Error,
}

impl Terminal {
    fn code(self) -> u8 {
        match self {
            Terminal::Length => 0,
            Terminal::Stop => 1,
            Terminal::Cancelled => 2,
            Terminal::Timeout => 3,
            Terminal::Error => 4,
        }
    }

    fn from_code(c: u8) -> Option<Self> {
        match c {
            0 => Some(Terminal::Length),
            1 => Some(Terminal::Stop),
            2 => Some(Terminal::Cancelled),
            3 => Some(Terminal::Timeout),
            4 => Some(Terminal::Error),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Terminal::Length => "length",
            Terminal::Stop => "stop",
            Terminal::Cancelled => "cancelled",
            Terminal::Timeout => "timeout",
            Terminal::Error => "error",
        }
    }
}

impl From<FinishReason> for Terminal {
    fn from(f: FinishReason) -> Self {
        match f {
            FinishReason::Length => Terminal::Length,
            FinishReason::Stop => Terminal::Stop,
            FinishReason::Cancelled => Terminal::Cancelled,
            FinishReason::Timeout => Terminal::Timeout,
        }
    }
}

/// A session's admission, with sampler parameters already resolved
/// against the `ServingConfig` in force when it was admitted. Replaying
/// `to_gen_request` under a *different* config still reproduces the
/// original stream: the resolved values ride along as explicit
/// per-request overrides.
#[derive(Debug, Clone, PartialEq)]
pub struct AdmitRecord {
    pub id: u64,
    pub seed: u64,
    pub temperature: f32,
    pub greedy: bool,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub stop_token: Option<i32>,
    pub timeout_ms: Option<u64>,
    pub prefix_cache: bool,
    pub priority: Priority,
    pub teacher: Option<Vec<i32>>,
}

impl AdmitRecord {
    pub fn to_gen_request(&self) -> GenRequest {
        GenRequest {
            prompt: self.prompt.clone(),
            max_new_tokens: self.max_new_tokens,
            teacher: self.teacher.clone(),
            stop_token: self.stop_token,
            temperature: Some(self.temperature),
            greedy: Some(self.greedy),
            seed: Some(self.seed),
            prefix_cache: self.prefix_cache,
            timeout_ms: self.timeout_ms,
            priority: self.priority,
        }
    }
}

fn put_admit_body(out: &mut Vec<u8>, a: &AdmitRecord) {
    put_u64(out, a.id);
    put_u64(out, a.seed);
    put_f32(out, a.temperature);
    put_u8(out, a.greedy as u8);
    put_u64(out, a.max_new_tokens as u64);
    match a.stop_token {
        Some(t) => {
            put_u8(out, 1);
            put_i32(out, t);
        }
        None => put_u8(out, 0),
    }
    match a.timeout_ms {
        Some(ms) => {
            put_u8(out, 1);
            put_u64(out, ms);
        }
        None => put_u8(out, 0),
    }
    put_u8(out, a.prefix_cache as u8);
    put_u8(out, priority_code(a.priority));
    put_u32(out, a.prompt.len() as u32);
    for &t in &a.prompt {
        put_i32(out, t);
    }
    match &a.teacher {
        Some(ts) => {
            put_u8(out, 1);
            put_u32(out, ts.len() as u32);
            for &t in ts {
                put_i32(out, t);
            }
        }
        None => put_u8(out, 0),
    }
}

fn read_admit_body(r: &mut Reader) -> Option<AdmitRecord> {
    let id = r.u64()?;
    let seed = r.u64()?;
    let temperature = r.f32()?;
    let greedy = r.u8()? != 0;
    let max_new_tokens = r.u64()? as usize;
    let stop_token = match r.u8()? {
        0 => None,
        _ => Some(r.i32()?),
    };
    let timeout_ms = match r.u8()? {
        0 => None,
        _ => Some(r.u64()?),
    };
    let prefix_cache = r.u8()? != 0;
    let priority = priority_from_code(r.u8()?)?;
    let n = r.u32()? as usize;
    let mut prompt = Vec::with_capacity(n.min(1 << 20));
    for _ in 0..n {
        prompt.push(r.i32()?);
    }
    let teacher = match r.u8()? {
        0 => None,
        _ => {
            let n = r.u32()? as usize;
            let mut ts = Vec::with_capacity(n.min(1 << 20));
            for _ in 0..n {
                ts.push(r.i32()?);
            }
            Some(ts)
        }
    };
    Some(AdmitRecord {
        id,
        seed,
        temperature,
        greedy,
        prompt,
        max_new_tokens,
        stop_token,
        timeout_ms,
        prefix_cache,
        priority,
        teacher,
    })
}

/// One decoded journal frame.
#[derive(Debug, Clone, PartialEq)]
enum Record {
    Admit(AdmitRecord),
    Step { id: u64, index: u32, token: i32, logprob: f64 },
    Finish { id: u64, reason: Terminal },
}

fn encode_admit(a: &AdmitRecord) -> Vec<u8> {
    let mut out = Vec::with_capacity(64 + 4 * a.prompt.len());
    put_u8(&mut out, TAG_ADMIT);
    put_admit_body(&mut out, a);
    out
}

fn encode_step(id: u64, index: u32, token: i32, logprob: f64) -> Vec<u8> {
    let mut out = Vec::with_capacity(25);
    put_u8(&mut out, TAG_STEP);
    put_u64(&mut out, id);
    put_u32(&mut out, index);
    put_i32(&mut out, token);
    put_f64(&mut out, logprob);
    out
}

fn encode_finish(id: u64, reason: Terminal) -> Vec<u8> {
    let mut out = Vec::with_capacity(10);
    put_u8(&mut out, TAG_FINISH);
    put_u64(&mut out, id);
    put_u8(&mut out, reason.code());
    out
}

/// Decode one frame payload. `None` means corrupt (unknown tag,
/// underrun, or trailing garbage) — the scanner treats it like a CRC
/// failure and truncates there.
fn decode_record(payload: &[u8]) -> Option<Record> {
    let mut r = Reader::new(payload);
    let rec = match r.u8()? {
        TAG_ADMIT => Record::Admit(read_admit_body(&mut r)?),
        TAG_STEP => Record::Step {
            id: r.u64()?,
            index: r.u32()?,
            token: r.i32()?,
            logprob: r.f64()?,
        },
        TAG_FINISH => Record::Finish { id: r.u64()?, reason: Terminal::from_code(r.u8()?)? },
        _ => return None,
    };
    if !r.done() {
        return None;
    }
    Some(rec)
}

/// Scan a journal byte buffer into records. Returns the decoded
/// records, the byte offset of the last valid frame boundary, and
/// whether a torn/corrupt tail was found past it.
fn scan_frames(bytes: &[u8]) -> (Vec<Record>, u64, bool) {
    let mut out = Vec::new();
    let mut pos = 0usize;
    while bytes.len() - pos >= 8 {
        let len = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(bytes[pos + 4..pos + 8].try_into().unwrap());
        if bytes.len() - pos - 8 < len {
            break; // torn: frame header promises more bytes than exist
        }
        let payload = &bytes[pos + 8..pos + 8 + len];
        if crc32(payload) != crc {
            break;
        }
        let Some(rec) = decode_record(payload) else { break };
        out.push(rec);
        pos += 8 + len;
    }
    (out, pos as u64, pos < bytes.len())
}

// ---------------------------------------------------------------------
// Session mirror
// ---------------------------------------------------------------------

/// Everything the journal knows about one session.
#[derive(Debug, Clone)]
pub struct SessionState {
    pub admit: AdmitRecord,
    /// Generated tokens in order (index i == the i-th STEP record).
    pub tokens: Vec<i32>,
    pub logprobs: Vec<f64>,
    pub finish: Option<Terminal>,
}

#[derive(Default)]
struct MirrorInner {
    sessions: BTreeMap<u64, SessionState>,
    /// Finished ids in completion order, for FIFO retention eviction.
    finished_order: VecDeque<u64>,
}

/// Shared in-memory view of the journal: the engine writes through it,
/// server threads read it to answer session-status and stream-resume
/// requests without touching disk.
#[derive(Clone, Default)]
pub struct SessionMirror(Arc<Mutex<MirrorInner>>);

impl SessionMirror {
    fn apply(&self, rec: Record) {
        match rec {
            Record::Admit(a) => self.apply_admit(a),
            Record::Step { id, index, token, logprob } => {
                self.apply_step(id, index, token, logprob)
            }
            Record::Finish { id, reason } => self.apply_finish(id, reason),
        }
    }

    fn apply_admit(&self, a: AdmitRecord) {
        let mut g = self.0.lock().unwrap();
        let id = a.id;
        g.sessions
            .entry(id)
            .or_insert_with(|| SessionState {
                admit: a,
                tokens: Vec::new(),
                logprobs: Vec::new(),
                finish: None,
            });
    }

    fn apply_step(&self, id: u64, index: u32, token: i32, logprob: f64) {
        let mut g = self.0.lock().unwrap();
        if let Some(s) = g.sessions.get_mut(&id) {
            // Only the next-in-order index extends the stream; a replay
            // of an already-mirrored index (checkpoint overlap) is a
            // no-op, and a gap (impossible from a correct engine) is
            // dropped rather than recorded out of place.
            if index as usize == s.tokens.len() {
                s.tokens.push(token);
                s.logprobs.push(logprob);
            }
        }
    }

    fn apply_finish(&self, id: u64, reason: Terminal) {
        let mut g = self.0.lock().unwrap();
        let Some(s) = g.sessions.get_mut(&id) else { return };
        if s.finish.is_some() {
            return;
        }
        s.finish = Some(reason);
        g.finished_order.push_back(id);
        while g.finished_order.len() > MAX_FINISHED_RETAINED {
            if let Some(old) = g.finished_order.pop_front() {
                g.sessions.remove(&old);
            }
        }
    }

    /// Replace the mirror's contents with a checkpoint snapshot.
    fn install(&self, states: Vec<SessionState>) {
        let mut g = self.0.lock().unwrap();
        g.sessions.clear();
        g.finished_order.clear();
        for s in states {
            let id = s.admit.id;
            if s.finish.is_some() {
                g.finished_order.push_back(id);
            }
            g.sessions.insert(id, s);
        }
    }

    pub fn get(&self, id: u64) -> Option<SessionState> {
        self.0.lock().unwrap().sessions.get(&id).cloned()
    }

    pub fn contains(&self, id: u64) -> bool {
        self.0.lock().unwrap().sessions.contains_key(&id)
    }

    /// Sessions with no terminal record, ascending by id (admission
    /// order — ids are monotonic).
    pub fn unfinished(&self) -> Vec<SessionState> {
        let g = self.0.lock().unwrap();
        g.sessions.values().filter(|s| s.finish.is_none()).cloned().collect()
    }

    pub fn max_id(&self) -> u64 {
        let g = self.0.lock().unwrap();
        g.sessions.keys().next_back().copied().unwrap_or(0)
    }

    fn snapshot(&self) -> Vec<SessionState> {
        self.0.lock().unwrap().sessions.values().cloned().collect()
    }
}

// ---------------------------------------------------------------------
// Checkpoint
// ---------------------------------------------------------------------

struct Checkpoint {
    epoch: u64,
    next_id: u64,
    sessions: Vec<SessionState>,
    /// Prefix-index topology at checkpoint time: (block hash, depth in
    /// blocks) per node. Informational — KV blocks do not survive a
    /// restart, so recovery rebuilds the tree by re-prefilling; the
    /// topology records what was cached for observability and tests.
    topology: Vec<(u64, u32)>,
}

fn encode_checkpoint_file(ck: &Checkpoint) -> Vec<u8> {
    let mut p = Vec::new();
    put_u32(&mut p, CKPT_MAGIC);
    put_u64(&mut p, ck.epoch);
    put_u64(&mut p, ck.next_id);
    put_u32(&mut p, ck.sessions.len() as u32);
    for s in &ck.sessions {
        put_admit_body(&mut p, &s.admit);
        put_u32(&mut p, s.tokens.len() as u32);
        for &t in &s.tokens {
            put_i32(&mut p, t);
        }
        for &lp in &s.logprobs {
            put_f64(&mut p, lp);
        }
        put_u8(&mut p, s.finish.map(Terminal::code).unwrap_or(255));
    }
    put_u32(&mut p, ck.topology.len() as u32);
    for &(hash, depth) in &ck.topology {
        put_u64(&mut p, hash);
        put_u32(&mut p, depth);
    }
    let mut out = Vec::with_capacity(p.len() + 8);
    put_u32(&mut out, p.len() as u32);
    put_u32(&mut out, crc32(&p));
    out.extend_from_slice(&p);
    out
}

fn decode_checkpoint_file(bytes: &[u8]) -> Option<Checkpoint> {
    if bytes.len() < 8 {
        return None;
    }
    let len = u32::from_le_bytes(bytes[0..4].try_into().unwrap()) as usize;
    let crc = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
    if bytes.len() - 8 < len {
        return None;
    }
    let payload = &bytes[8..8 + len];
    if crc32(payload) != crc {
        return None;
    }
    let mut r = Reader::new(payload);
    if r.u32()? != CKPT_MAGIC {
        return None;
    }
    let epoch = r.u64()?;
    let next_id = r.u64()?;
    let n_sessions = r.u32()? as usize;
    let mut sessions = Vec::with_capacity(n_sessions.min(1 << 16));
    for _ in 0..n_sessions {
        let admit = read_admit_body(&mut r)?;
        let n = r.u32()? as usize;
        let mut tokens = Vec::with_capacity(n.min(1 << 20));
        for _ in 0..n {
            tokens.push(r.i32()?);
        }
        let mut logprobs = Vec::with_capacity(n.min(1 << 20));
        for _ in 0..n {
            logprobs.push(r.f64()?);
        }
        let finish = match r.u8()? {
            255 => None,
            c => Some(Terminal::from_code(c)?),
        };
        sessions.push(SessionState { admit, tokens, logprobs, finish });
    }
    let n_topo = r.u32()? as usize;
    let mut topology = Vec::with_capacity(n_topo.min(1 << 20));
    for _ in 0..n_topo {
        let hash = r.u64()?;
        let depth = r.u32()?;
        topology.push((hash, depth));
    }
    if !r.done() {
        return None;
    }
    Some(Checkpoint { epoch, next_id, sessions, topology })
}

// ---------------------------------------------------------------------
// Journal
// ---------------------------------------------------------------------

struct Inner {
    file: File,
    epoch: u64,
    /// Bytes appended this epoch (valid frames only).
    len: u64,
    /// Bytes covered by the last successful `sync_data` — everything a
    /// hard abort is guaranteed to preserve.
    durable_len: u64,
    /// Frames appended since the last fsync.
    unsynced: usize,
    /// Set by `simulate_crash`: all further appends (and mirror
    /// updates) are dropped, modeling a dead process.
    poisoned: bool,
    /// Topology snapshot from the last checkpoint (loaded or written).
    ckpt_topology: Vec<(u64, u32)>,
}

/// Append-only, checksummed, fsync-batched session journal with
/// checkpoint rotation. All methods take `&self` (the engine journals
/// from `&self` contexts); appends never fail the caller — I/O errors
/// are swallowed into `journal_append_errors` so a sick disk degrades
/// durability, not serving.
pub struct Journal {
    dir: PathBuf,
    fsync_every: usize,
    metrics: Arc<Metrics>,
    mirror: SessionMirror,
    next_id_floor: u64,
    inner: Mutex<Inner>,
}

fn journal_path(dir: &Path, epoch: u64) -> PathBuf {
    dir.join(format!("journal.{epoch}.bin"))
}

impl Journal {
    /// Open (or create) the journal in `dir`, recovering state from the
    /// checkpoint + current-epoch journal tail. An invalid checkpoint
    /// is ignored (counted in `journal_checkpoint_invalid`); a torn
    /// journal tail is truncated (counted in `journal_torn_tail`).
    pub fn open(dir: &str, fsync_every: usize, metrics: Arc<Metrics>) -> Result<Self> {
        let dir = PathBuf::from(dir);
        fs::create_dir_all(&dir)
            .with_context(|| format!("creating journal dir {}", dir.display()))?;
        let mirror = SessionMirror::default();
        let mut epoch = 0u64;
        let mut next_floor = 1u64;
        let mut ckpt_topology = Vec::new();
        let ckpt_path = dir.join(CHECKPOINT_FILE);
        if ckpt_path.exists() {
            match fs::read(&ckpt_path).ok().and_then(|b| decode_checkpoint_file(&b)) {
                Some(ck) => {
                    epoch = ck.epoch;
                    next_floor = ck.next_id;
                    ckpt_topology = ck.topology;
                    mirror.install(ck.sessions);
                }
                None => metrics.inc("journal_checkpoint_invalid"),
            }
        }
        let path = journal_path(&dir, epoch);
        let mut valid_len = 0u64;
        if path.exists() {
            let bytes =
                fs::read(&path).with_context(|| format!("reading {}", path.display()))?;
            let (records, vlen, torn) = scan_frames(&bytes);
            valid_len = vlen;
            if torn {
                metrics.inc("journal_torn_tail");
            }
            for rec in records {
                mirror.apply(rec);
            }
        }
        // Journals from other epochs are stale (their state is covered
        // by the checkpoint) or half-rotated garbage: remove them.
        if let Ok(rd) = fs::read_dir(&dir) {
            for e in rd.flatten() {
                let name = e.file_name();
                let name = name.to_string_lossy();
                let stale = name
                    .strip_prefix("journal.")
                    .and_then(|r| r.strip_suffix(".bin"))
                    .and_then(|s| s.parse::<u64>().ok())
                    .is_some_and(|ep| ep != epoch);
                if stale {
                    let _ = fs::remove_file(e.path());
                }
            }
        }
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .with_context(|| format!("opening {}", path.display()))?;
        // Drop the torn tail on disk so the next append starts at a
        // clean frame boundary.
        file.set_len(valid_len)
            .with_context(|| format!("truncating torn tail of {}", path.display()))?;
        let next_id_floor = next_floor.max(mirror.max_id() + 1);
        Ok(Self {
            dir,
            fsync_every: fsync_every.max(1),
            metrics,
            mirror,
            next_id_floor,
            inner: Mutex::new(Inner {
                file,
                epoch,
                len: valid_len,
                durable_len: valid_len,
                unsynced: 0,
                poisoned: false,
                ckpt_topology,
            }),
        })
    }

    /// Lowest session id a recovered engine may assign: above every id
    /// the journal has ever seen, so recovered and fresh sessions never
    /// collide.
    pub fn next_id_floor(&self) -> u64 {
        self.next_id_floor
    }

    /// Shared read view for the server's resume endpoints.
    pub fn mirror(&self) -> SessionMirror {
        self.mirror.clone()
    }

    pub fn unfinished_sessions(&self) -> Vec<SessionState> {
        self.mirror.unfinished()
    }

    pub fn epoch(&self) -> u64 {
        self.inner.lock().unwrap().epoch
    }

    /// Bytes a hard abort is guaranteed to preserve (<= bytes written).
    pub fn durable_bytes(&self) -> u64 {
        self.inner.lock().unwrap().durable_len
    }

    pub fn bytes_written(&self) -> u64 {
        self.inner.lock().unwrap().len
    }

    pub fn is_poisoned(&self) -> bool {
        self.inner.lock().unwrap().poisoned
    }

    /// Topology snapshot from the most recent checkpoint.
    pub fn checkpoint_topology(&self) -> Vec<(u64, u32)> {
        self.inner.lock().unwrap().ckpt_topology.clone()
    }

    fn append_locked(&self, g: &mut Inner, payload: &[u8]) {
        let mut frame = Vec::with_capacity(payload.len() + 8);
        put_u32(&mut frame, payload.len() as u32);
        put_u32(&mut frame, crc32(payload));
        frame.extend_from_slice(payload);
        if g.file.write_all(&frame).is_err() {
            self.metrics.inc("journal_append_errors");
            return;
        }
        g.len += frame.len() as u64;
        g.unsynced += 1;
        self.metrics.add("journal_bytes", frame.len() as u64);
        if g.unsynced >= self.fsync_every && g.file.sync_data().is_ok() {
            self.metrics.inc("journal_fsyncs");
            g.durable_len = g.len;
            g.unsynced = 0;
        }
    }

    /// Journal a session admission (resolved sampler parameters).
    pub fn admit(&self, rec: &AdmitRecord) {
        let mut g = self.inner.lock().unwrap();
        if g.poisoned {
            return;
        }
        self.mirror.apply_admit(rec.clone());
        self.append_locked(&mut g, &encode_admit(rec));
    }

    /// Journal one sampled/teacher-forced token.
    pub fn step(&self, id: u64, index: usize, token: i32, logprob: f64) {
        let mut g = self.inner.lock().unwrap();
        if g.poisoned {
            return;
        }
        self.mirror.apply_step(id, index as u32, token, logprob);
        self.append_locked(&mut g, &encode_step(id, index as u32, token, logprob));
    }

    /// Journal a session's terminal record.
    pub fn finish(&self, id: u64, reason: Terminal) {
        let mut g = self.inner.lock().unwrap();
        if g.poisoned {
            return;
        }
        self.mirror.apply_finish(id, reason);
        self.append_locked(&mut g, &encode_finish(id, reason));
    }

    /// Write a checkpoint (atomic replace) and rotate the journal to a
    /// fresh epoch, deleting the superseded segment. A crash at any
    /// point is safe: either the old checkpoint + old journal or the
    /// new checkpoint (whose snapshot covers the old journal) wins, and
    /// `open` discards journals from non-checkpoint epochs.
    pub fn checkpoint(&self, next_id: u64, topology: &[(u64, u32)]) -> Result<()> {
        let sessions = self.mirror.snapshot();
        let mut g = self.inner.lock().unwrap();
        if g.poisoned {
            return Ok(());
        }
        let new_epoch = g.epoch + 1;
        let ck = Checkpoint {
            epoch: new_epoch,
            next_id: next_id.max(self.next_id_floor),
            sessions,
            topology: topology.to_vec(),
        };
        let bytes = encode_checkpoint_file(&ck);
        crate::util::fsio::write_atomic(self.dir.join(CHECKPOINT_FILE), &bytes)
            .context("writing checkpoint")?;
        let new_path = journal_path(&self.dir, new_epoch);
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&new_path)
            .with_context(|| format!("opening {}", new_path.display()))?;
        file.set_len(0)?;
        let old_path = journal_path(&self.dir, g.epoch);
        g.file = file;
        g.epoch = new_epoch;
        g.len = 0;
        g.durable_len = 0;
        g.unsynced = 0;
        g.ckpt_topology = topology.to_vec();
        let _ = fs::remove_file(old_path);
        self.metrics.inc("journal_checkpoints");
        Ok(())
    }

    /// Model a hard abort (`crash@STEP` fault): everything past the
    /// last fsync is torn off the disk image and the journal stops
    /// accepting writes, as if the process died mid-append. A fresh
    /// `open` on the same directory sees exactly what a real crash
    /// would have left behind.
    pub fn simulate_crash(&self) {
        let mut g = self.inner.lock().unwrap();
        if g.poisoned {
            return;
        }
        let _ = g.file.set_len(g.durable_len);
        g.poisoned = true;
        self.metrics.inc("journal_simulated_crashes");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("radar-journal-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn dir_str(p: &Path) -> String {
        p.to_string_lossy().into_owned()
    }

    fn admit(id: u64) -> AdmitRecord {
        AdmitRecord {
            id,
            seed: 42 ^ id,
            temperature: 0.7,
            greedy: false,
            prompt: (0..20).map(|t| (t % 7) as i32).collect(),
            max_new_tokens: 16,
            stop_token: Some(10),
            timeout_ms: None,
            prefix_cache: true,
            priority: Priority::Normal,
            teacher: None,
        }
    }

    fn metrics() -> Arc<Metrics> {
        Arc::new(Metrics::new())
    }

    #[test]
    fn crc32_known_vector() {
        // IEEE CRC32 of "123456789" is the classic check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_ne!(crc32(b"a"), crc32(b"b"));
    }

    #[test]
    fn admit_record_roundtrips_all_fields() {
        let mut a = admit(3);
        a.teacher = Some(vec![1, -2, 3]);
        a.timeout_ms = Some(1234);
        a.priority = Priority::High;
        a.greedy = true;
        let enc = encode_admit(&a);
        match decode_record(&enc) {
            Some(Record::Admit(back)) => assert_eq!(back, a),
            other => panic!("bad decode: {other:?}"),
        }
        // Trailing garbage makes the payload corrupt, not misparsed.
        let mut longer = enc.clone();
        longer.push(0);
        assert!(decode_record(&longer).is_none());
        // Truncation is corrupt, never a panic.
        for cut in 0..enc.len() {
            let _ = decode_record(&enc[..cut]);
        }
    }

    #[test]
    fn terminal_and_priority_codes_roundtrip() {
        for t in [
            Terminal::Length,
            Terminal::Stop,
            Terminal::Cancelled,
            Terminal::Timeout,
            Terminal::Error,
        ] {
            assert_eq!(Terminal::from_code(t.code()), Some(t));
        }
        assert_eq!(Terminal::from_code(9), None);
        for p in [Priority::Batch, Priority::Normal, Priority::High] {
            assert_eq!(priority_from_code(priority_code(p)), Some(p));
        }
        assert_eq!(priority_from_code(7), None);
        assert_eq!(Terminal::from(FinishReason::Stop), Terminal::Stop);
        assert_eq!(Terminal::from(FinishReason::Timeout).as_str(), "timeout");
    }

    #[test]
    fn to_gen_request_pins_resolved_sampler_values() {
        let a = admit(5);
        let req = a.to_gen_request();
        assert_eq!(req.seed, Some(a.seed));
        assert_eq!(req.temperature, Some(a.temperature));
        assert_eq!(req.greedy, Some(a.greedy));
        assert_eq!(req.prompt, a.prompt);
        assert_eq!(req.max_new_tokens, a.max_new_tokens);
        assert_eq!(req.stop_token, a.stop_token);
    }

    #[test]
    fn journal_reopen_recovers_unfinished_sessions() {
        let d = tmp_dir("reopen");
        {
            let j = Journal::open(&dir_str(&d), 1, metrics()).unwrap();
            j.admit(&admit(1));
            j.step(1, 0, 65, -0.5);
            j.step(1, 1, 66, -0.25);
            j.finish(1, Terminal::Stop);
            j.admit(&admit(2));
            j.step(2, 0, 70, -1.0);
        }
        let j = Journal::open(&dir_str(&d), 1, metrics()).unwrap();
        let open = j.unfinished_sessions();
        assert_eq!(open.len(), 1);
        assert_eq!(open[0].admit.id, 2);
        assert_eq!(open[0].tokens, vec![70]);
        assert_eq!(open[0].logprobs, vec![-1.0]);
        let done = j.mirror().get(1).unwrap();
        assert_eq!(done.finish, Some(Terminal::Stop));
        assert_eq!(done.tokens, vec![65, 66]);
        assert_eq!(j.next_id_floor(), 3);
        let _ = fs::remove_dir_all(&d);
    }

    #[test]
    fn torn_tail_is_truncated_not_fatal() {
        let d = tmp_dir("torn");
        let clean_len;
        {
            let j = Journal::open(&dir_str(&d), 1, metrics()).unwrap();
            j.admit(&admit(1));
            j.step(1, 0, 65, -0.5);
            clean_len = j.bytes_written();
        }
        // A crash mid-append leaves half a frame on disk.
        let path = journal_path(&d, 0);
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(&[0x19, 0x00, 0x00, 0x00, 0xde, 0xad]).unwrap();
        drop(f);
        let m = metrics();
        let j = Journal::open(&dir_str(&d), 1, m.clone()).unwrap();
        assert_eq!(m.counter("journal_torn_tail"), 1);
        let open = j.unfinished_sessions();
        assert_eq!(open.len(), 1);
        assert_eq!(open[0].tokens, vec![65]);
        // The tail was physically removed: appends restart at the
        // clean boundary.
        assert_eq!(fs::metadata(&path).unwrap().len(), clean_len);
        let _ = fs::remove_dir_all(&d);
    }

    #[test]
    fn corrupt_crc_drops_record_and_tail() {
        let d = tmp_dir("crc");
        let len_after_two;
        {
            let j = Journal::open(&dir_str(&d), 1, metrics()).unwrap();
            j.admit(&admit(1));
            j.step(1, 0, 65, -0.5);
            len_after_two = j.bytes_written();
            j.step(1, 1, 66, -0.25);
        }
        // Flip a byte inside the last frame's payload.
        let path = journal_path(&d, 0);
        let mut bytes = fs::read(&path).unwrap();
        let idx = len_after_two as usize + 12;
        bytes[idx] ^= 0xFF;
        fs::write(&path, &bytes).unwrap();
        let m = metrics();
        let j = Journal::open(&dir_str(&d), 1, m.clone()).unwrap();
        assert_eq!(m.counter("journal_torn_tail"), 1);
        let open = j.unfinished_sessions();
        assert_eq!(open[0].tokens, vec![65], "corrupt step dropped, prefix kept");
        assert_eq!(fs::metadata(&path).unwrap().len(), len_after_two);
        let _ = fs::remove_dir_all(&d);
    }

    #[test]
    fn fsync_batching_bounds_what_a_crash_loses() {
        let d = tmp_dir("fsync");
        {
            // Large batch: nothing fsynced yet, so a hard abort tears
            // off everything after the last sync point (here: all).
            let m = metrics();
            let j = Journal::open(&dir_str(&d), 1000, m.clone()).unwrap();
            j.admit(&admit(1));
            j.step(1, 0, 65, -0.5);
            assert_eq!(j.durable_bytes(), 0);
            assert_eq!(m.counter("journal_fsyncs"), 0);
            j.simulate_crash();
            assert!(j.is_poisoned());
            // Poisoned journal drops everything, like a dead process.
            j.step(1, 1, 66, -0.25);
            j.finish(1, Terminal::Length);
        }
        let j = Journal::open(&dir_str(&d), 1, metrics()).unwrap();
        assert!(j.unfinished_sessions().is_empty(), "unsynced records are gone");
        drop(j);

        // fsync_every=1: every record is durable before the crash.
        let d2 = tmp_dir("fsync1");
        {
            let m = metrics();
            let j = Journal::open(&dir_str(&d2), 1, m.clone()).unwrap();
            j.admit(&admit(1));
            j.step(1, 0, 65, -0.5);
            assert_eq!(j.durable_bytes(), j.bytes_written());
            assert_eq!(m.counter("journal_fsyncs"), 2);
            j.simulate_crash();
        }
        let j = Journal::open(&dir_str(&d2), 1, metrics()).unwrap();
        let open = j.unfinished_sessions();
        assert_eq!(open.len(), 1);
        assert_eq!(open[0].tokens, vec![65]);
        let _ = fs::remove_dir_all(&d);
        let _ = fs::remove_dir_all(&d2);
    }

    #[test]
    fn checkpoint_rotates_epoch_and_bounds_replay() {
        let d = tmp_dir("ckpt");
        {
            let j = Journal::open(&dir_str(&d), 1, metrics()).unwrap();
            j.admit(&admit(1));
            j.step(1, 0, 65, -0.5);
            j.checkpoint(9, &[(0xabcd, 1), (0x1234, 2)]).unwrap();
            assert_eq!(j.epoch(), 1);
            assert!(!journal_path(&d, 0).exists(), "old epoch removed");
            assert!(journal_path(&d, 1).exists());
            // Post-checkpoint records land in the new epoch.
            j.step(1, 1, 66, -0.25);
        }
        let j = Journal::open(&dir_str(&d), 1, metrics()).unwrap();
        assert_eq!(j.epoch(), 1);
        let open = j.unfinished_sessions();
        assert_eq!(open.len(), 1);
        assert_eq!(open[0].tokens, vec![65, 66], "checkpoint state + journal tail merge");
        assert_eq!(j.next_id_floor(), 9);
        assert_eq!(j.checkpoint_topology(), vec![(0xabcd, 1), (0x1234, 2)]);
        let _ = fs::remove_dir_all(&d);
    }

    #[test]
    fn invalid_checkpoint_is_ignored_not_fatal() {
        let d = tmp_dir("badckpt");
        {
            let j = Journal::open(&dir_str(&d), 1, metrics()).unwrap();
            j.admit(&admit(1));
        }
        fs::write(d.join(CHECKPOINT_FILE), b"not a checkpoint").unwrap();
        let m = metrics();
        let j = Journal::open(&dir_str(&d), 1, m.clone()).unwrap();
        assert_eq!(m.counter("journal_checkpoint_invalid"), 1);
        // Epoch falls back to 0, whose journal still has the session.
        assert_eq!(j.unfinished_sessions().len(), 1);
        let _ = fs::remove_dir_all(&d);
    }

    #[test]
    fn finished_retention_evicts_fifo() {
        let mirror = SessionMirror::default();
        for id in 1..=(MAX_FINISHED_RETAINED as u64 + 10) {
            mirror.apply_admit(admit(id));
            mirror.apply_finish(id, Terminal::Length);
        }
        assert!(!mirror.contains(1), "oldest finished session evicted");
        assert!(mirror.contains(MAX_FINISHED_RETAINED as u64 + 10));
        // Unfinished sessions are never evicted by retention.
        let mirror = SessionMirror::default();
        mirror.apply_admit(admit(1));
        for id in 2..=(MAX_FINISHED_RETAINED as u64 + 10) {
            mirror.apply_admit(admit(id));
            mirror.apply_finish(id, Terminal::Length);
        }
        assert!(mirror.contains(1));
        assert_eq!(mirror.unfinished().len(), 1);
    }

    #[test]
    fn mirror_step_ignores_duplicates_and_gaps() {
        let mirror = SessionMirror::default();
        mirror.apply_admit(admit(1));
        mirror.apply_step(1, 0, 65, -0.5);
        mirror.apply_step(1, 0, 99, -9.9); // duplicate index: no-op
        mirror.apply_step(1, 5, 99, -9.9); // gap: dropped
        mirror.apply_step(1, 1, 66, -0.25);
        let s = mirror.get(1).unwrap();
        assert_eq!(s.tokens, vec![65, 66]);
        // Finish is idempotent.
        mirror.apply_finish(1, Terminal::Stop);
        mirror.apply_finish(1, Terminal::Error);
        assert_eq!(mirror.get(1).unwrap().finish, Some(Terminal::Stop));
    }
}
