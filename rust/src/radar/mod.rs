//! The paper's contribution: the Radar hierarchical index.
//!
//! Per sequence, per (layer, head), maintain segment summaries
//! `mean(phi(k_j), j in segment)` (Eq. 5) over the covered prefix
//! [0, boundary); tokens [boundary, t) form the unregistered buffer W
//! (Alg. 1 line 13), always attended as a sliding window.
//!
//! Restructure trigger (Alg. 1 line 8): whenever sqrt(t) is an integer,
//! set c = sqrt(t) and rebuild all c segments of length c from the
//! per-token features stored in the KV cache — O(t) work, amortized
//! O(sqrt(t))/step.
//!
//! Query (Alg. 1 lines 16-21): score every segment with
//! `phi(q)^T seg_feat` (Eq. 6), take the top-k, attend to their tokens
//! plus W plus the sinks.

use crate::kvcache::{BlockPool, SeqCache};

/// Integer square root (floor), exact for every `usize`.
///
/// Pure-integer Newton iteration seeded at `n/2 + 1` (always an
/// over-approximation of sqrt(n) for n >= 2, so the sequence decreases
/// monotonically onto the floor and cannot overflow). The previous
/// float-seeded loop-correction relied on `f64::sqrt` rounding, which
/// loses integer precision above 2^53.
pub fn isqrt(n: usize) -> usize {
    if n < 2 {
        return n;
    }
    let mut x = (n >> 1) + 1;
    loop {
        let y = (x + n / x) / 2;
        if y >= x {
            debug_assert!(
                x * x <= n && (x + 1).checked_mul(x + 1).map_or(true, |s| s > n),
                "isqrt({n}) = {x} violates the floor invariant"
            );
            return x;
        }
        x = y;
    }
}

/// Immutable snapshot of segment summaries for a token prefix,
/// shareable across sequences (`Arc`'d into the prefix cache). Segment
/// means are a pure function of the prefix tokens, so any sequence
/// whose cache holds the same prefix can adopt them verbatim — the
/// restructure skips recomputing those segments when its `c` matches.
#[derive(Debug, Clone)]
pub struct FrozenSegments {
    pub lh: usize,
    pub n_feat: usize,
    /// Segment length the summaries were computed with.
    pub c: usize,
    /// Number of segments; they cover tokens [0, c * n_segs).
    pub n_segs: usize,
    /// Tokens covered (== c * n_segs).
    pub boundary: usize,
    /// Layout [lh, n_segs, n_feat].
    seg_feats: Vec<f32>,
}

impl FrozenSegments {
    pub fn seg_feat(&self, p: usize, s: usize) -> &[f32] {
        &self.seg_feats[(p * self.n_segs + s) * self.n_feat..][..self.n_feat]
    }

    /// Heap footprint, for eviction accounting.
    pub fn bytes(&self) -> usize {
        self.seg_feats.len() * std::mem::size_of::<f32>()
    }
}

/// Per-sequence segment index for all (layer, head) planes.
pub struct RadarIndex {
    lh: usize,
    n_feat: usize,
    /// Current segment length c (0 before the first restructure).
    pub c: usize,
    /// Number of segments; they cover tokens [0, c * n_segs).
    pub n_segs: usize,
    /// Segment summaries, layout [lh, n_segs, n_feat].
    seg_feats: Vec<f32>,
    /// Tokens covered by segments (== c * n_segs).
    pub boundary: usize,
    /// Restructure count (telemetry / tests).
    pub restructures: usize,
    /// Segments adopted from a frozen donor instead of recomputed
    /// (telemetry / tests); reset on every restructure.
    pub adopted_segs: usize,
}

impl RadarIndex {
    pub fn new(lh: usize, n_feat: usize) -> Self {
        Self {
            lh,
            n_feat,
            c: 0,
            n_segs: 0,
            seg_feats: Vec::new(),
            boundary: 0,
            restructures: 0,
            adopted_segs: 0,
        }
    }

    /// Window W = tokens [boundary, t).
    pub fn window_start(&self) -> usize {
        self.boundary
    }

    /// Alg. 1 line 8: called after the cache holds `t` tokens.
    /// Returns true if a restructure happened.
    pub fn maybe_restructure(&mut self, seq: &SeqCache, pool: &BlockPool, t: usize) -> bool {
        self.maybe_restructure_with(seq, pool, t, None)
    }

    /// `maybe_restructure`, optionally adopting precomputed segment
    /// means from a frozen donor covering a shared prefix.
    pub fn maybe_restructure_with(
        &mut self,
        seq: &SeqCache,
        pool: &BlockPool,
        t: usize,
        donor: Option<&FrozenSegments>,
    ) -> bool {
        let r = isqrt(t);
        if r * r != t || r == 0 {
            return false;
        }
        self.restructure(seq, pool, r, t, donor);
        true
    }

    /// Post-prefill initialization: restructure at c = isqrt(t) even if
    /// t is not a perfect square (segments cover [0, (t/c)*c), the
    /// remainder becomes the window W).
    pub fn force_restructure(&mut self, seq: &SeqCache, pool: &BlockPool) {
        self.force_restructure_with(seq, pool, None)
    }

    /// `force_restructure` with an optional frozen donor.
    pub fn force_restructure_with(
        &mut self,
        seq: &SeqCache,
        pool: &BlockPool,
        donor: Option<&FrozenSegments>,
    ) {
        let t = seq.len();
        let c = isqrt(t);
        if c > 0 {
            self.restructure(seq, pool, c, t, donor);
        }
    }

    /// Snapshot the first segments covering at most `max_tokens` tokens
    /// (rounded down to whole segments). Returns None before the first
    /// restructure or when no whole segment fits.
    pub fn freeze(&self, max_tokens: usize) -> Option<FrozenSegments> {
        if self.c == 0 {
            return None;
        }
        let n = (max_tokens / self.c).min(self.n_segs);
        if n == 0 {
            return None;
        }
        let nf = self.n_feat;
        let mut feats = vec![0.0f32; self.lh * n * nf];
        for p in 0..self.lh {
            for s in 0..n {
                feats[(p * n + s) * nf..][..nf]
                    .copy_from_slice(&self.seg_feats[(p * self.n_segs + s) * nf..][..nf]);
            }
        }
        Some(FrozenSegments {
            lh: self.lh,
            n_feat: nf,
            c: self.c,
            n_segs: n,
            boundary: n * self.c,
            seg_feats: feats,
        })
    }

    /// Rebuild segments with length c covering [0, n_segs * c). When a
    /// donor with the *same* c is supplied, segments it covers are
    /// copied instead of recomputed — bit-identical to recomputation
    /// (same tokens, same summation order) but O(n_feat) per segment.
    fn restructure(
        &mut self,
        seq: &SeqCache,
        pool: &BlockPool,
        c: usize,
        t: usize,
        donor: Option<&FrozenSegments>,
    ) {
        let n_segs = t / c;
        let nf = self.n_feat;
        self.seg_feats.clear();
        self.seg_feats.resize(self.lh * n_segs * nf, 0.0);
        // A donor only helps when its segment geometry matches exactly;
        // anything else would change the means and break determinism.
        let donor = donor.filter(|d| d.c == c && d.lh == self.lh && d.n_feat == nf);
        let adoptable = donor.map_or(0, |d| d.n_segs.min(n_segs));
        self.adopted_segs = 0;
        let n_heads = pool_heads(pool);
        let inv_c = 1.0 / c as f32;
        for p in 0..self.lh {
            let (l, h) = (p / n_heads, p % n_heads);
            for s in 0..n_segs {
                let dst = (p * n_segs + s) * nf;
                if s < adoptable {
                    self.seg_feats[dst..dst + nf]
                        .copy_from_slice(donor.unwrap().seg_feat(p, s));
                    self.adopted_segs += 1;
                    continue;
                }
                for tok in s * c..(s + 1) * c {
                    let f = seq.feat(pool, l, h, tok);
                    let acc = &mut self.seg_feats[dst..dst + nf];
                    for (a, &x) in acc.iter_mut().zip(f) {
                        *a += x;
                    }
                }
                for a in &mut self.seg_feats[dst..dst + nf] {
                    *a *= inv_c;
                }
            }
        }
        self.c = c;
        self.n_segs = n_segs;
        self.boundary = n_segs * c;
        self.restructures += 1;
    }

    /// Segment scores for plane (l, h) against phi(q) — Eq. 6.
    /// `out` must have length n_segs.
    pub fn scores(&self, p: usize, q_feat: &[f32], out: &mut Vec<f32>) {
        debug_assert_eq!(q_feat.len(), self.n_feat);
        out.clear();
        let nf = self.n_feat;
        for s in 0..self.n_segs {
            let seg = &self.seg_feats[(p * self.n_segs + s) * nf..][..nf];
            let mut dot = 0.0f32;
            for i in 0..nf {
                dot += seg[i] * q_feat[i];
            }
            out.push(dot);
        }
    }

    /// Raw summary access (tests / Fig. 7 harness).
    pub fn seg_feat(&self, p: usize, s: usize) -> &[f32] {
        &self.seg_feats[(p * self.n_segs + s) * self.n_feat..][..self.n_feat]
    }

    /// Chaos hook (`nan@` fault injection): overwrite every segment
    /// summary with NaN so the next query trips the anomaly detector
    /// and falls back to exact attention. A later restructure rebuilds
    /// clean summaries from the (untouched) per-token features.
    pub fn poison_with_nan(&mut self) {
        for x in self.seg_feats.iter_mut() {
            *x = f32::NAN;
        }
    }
}

fn pool_heads(pool: &BlockPool) -> usize {
    pool.config().n_heads
}

/// Indices of the top-k values (k <= scores.len()), unordered.
///
/// O(n) expected via `select_nth_unstable_by` partial selection.
/// Ties are broken deterministically by index: among equal scores the
/// *lowest* indices win, so the result is a pure function of the input
/// regardless of selection-internals ordering.
pub fn top_k_indices(scores: &[f32], k: usize) -> Vec<usize> {
    if k == 0 || scores.is_empty() {
        return Vec::new();
    }
    let k = k.min(scores.len());
    // f32 isn't Ord; map to an order-preserving i64 via the sign-folded
    // bit pattern (total order; NaN-free inputs by construction).
    let to_ord = |x: f32| -> i64 {
        let b = x.to_bits() as i32;
        if b >= 0 { b as i64 } else { i32::MIN as i64 - b as i64 }
    };
    let mut keyed: Vec<(i64, usize)> =
        scores.iter().enumerate().map(|(i, &s)| (to_ord(s), i)).collect();
    if k < keyed.len() {
        // Descending score, ascending index on ties; everything before
        // rank k is strictly "better or equal with a lower index".
        keyed.select_nth_unstable_by(k - 1, |a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
        keyed.truncate(k);
    }
    keyed.into_iter().map(|(_, i)| i).collect()
}

/// Exact segment scores (the Fig. 5 "exact top-k" ablation):
/// sum over the segment of exp(q . k_j / sqrt(d)).
pub fn exact_segment_scores(
    seq: &SeqCache,
    pool: &BlockPool,
    l: usize,
    h: usize,
    q: &[f32],
    c: usize,
    n_segs: usize,
    out: &mut Vec<f32>,
) {
    out.clear();
    let d = q.len();
    let scale = 1.0 / (d as f32).sqrt();
    for s in 0..n_segs {
        let mut acc = 0.0f32;
        for tok in s * c..(s + 1) * c {
            let k = seq.key(pool, l, h, tok);
            let mut dot = 0.0f32;
            for i in 0..d {
                dot += q[i] * k[i];
            }
            acc += (dot * scale).exp();
        }
        out.push(acc);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::util::prng::SplitMix64;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            d_head: 4,
            d_ffn: 16,
            n_feat: 6,
            max_train_len: 64,
            vocab: 16,
        }
    }

    fn build_seq(t: usize) -> (BlockPool, SeqCache) {
        let c = cfg();
        let mut pool = BlockPool::new(&c, 6, 1000);
        let mut seq = SeqCache::new(6);
        let mut rng = SplitMix64::new(1);
        for _ in 0..t {
            let k: Vec<f32> = (0..16).map(|_| rng.next_f32()).collect();
            let v = k.clone();
            let f: Vec<f32> = (0..24).map(|_| rng.next_f32()).collect();
            seq.append(&mut pool, &k, &v, &f).unwrap();
        }
        (pool, seq)
    }

    #[test]
    fn isqrt_exact() {
        for t in 0..2000usize {
            let r = isqrt(t);
            assert!(r * r <= t && (r + 1) * (r + 1) > t, "isqrt({t}) = {r}");
        }
    }

    /// Overflow-safe floor-sqrt invariant: r^2 <= n < (r+1)^2.
    fn isqrt_invariant(n: usize) -> Result<(), String> {
        let r = isqrt(n);
        if r.checked_mul(r).map_or(true, |s| s > n) {
            return Err(format!("isqrt({n}) = {r}: r^2 > n (or overflows)"));
        }
        if (r + 1).checked_mul(r + 1).map_or(false, |s| s <= n) {
            return Err(format!("isqrt({n}) = {r}: (r+1)^2 <= n"));
        }
        Ok(())
    }

    #[test]
    fn isqrt_property_sweep() {
        use crate::util::minitest::check;
        // Boundary values where the old float-seeded version could go
        // wrong: perfect squares and their neighbors across the whole
        // width of usize, including above 2^53 where f64 is lossy.
        for b in 0..=(usize::BITS / 2 - 1) {
            let s = 1usize << b;
            for sq in [s * s, s * s + 1, (s * s).wrapping_sub(1)] {
                isqrt_invariant(sq).unwrap();
            }
        }
        for n in [usize::MAX, usize::MAX - 1, (1 << 53) + 1, (1 << 60) + 3] {
            isqrt_invariant(n).unwrap();
        }
        // Randomized sweep over the full usize range, with shrinking.
        check(
            17,
            500,
            |r| r.next_u64() as usize,
            |&n| isqrt_invariant(n),
        );
        // And over small values, where off-by-ones would bite the
        // restructure schedule.
        check(19, 500, |r| r.below(4096) as usize, |&n| isqrt_invariant(n));
    }

    #[test]
    fn restructures_only_at_perfect_squares() {
        let (pool, seq) = build_seq(150);
        let mut idx = RadarIndex::new(4, 6);
        let mut events = Vec::new();
        for t in 1..=150 {
            if idx.maybe_restructure(&seq, &pool, t) {
                events.push(t);
            }
        }
        assert_eq!(events, vec![1, 4, 9, 16, 25, 36, 49, 64, 81, 100, 121, 144]);
        assert_eq!(idx.c, 12);
        assert_eq!(idx.n_segs, 12);
        assert_eq!(idx.boundary, 144);
        // Window = tokens [144, 150): length <= 2*sqrt(t)+1
        assert!(150 - idx.window_start() <= 2 * 12 + 1);
    }

    #[test]
    fn summaries_equal_feature_means() {
        let (pool, seq) = build_seq(64);
        let mut idx = RadarIndex::new(4, 6);
        assert!(idx.maybe_restructure(&seq, &pool, 64));
        assert_eq!((idx.c, idx.n_segs), (8, 8));
        // plane (l=1,h=0) = p2, segment 3 covers tokens 24..32
        let got = idx.seg_feat(2, 3);
        let mut want = vec![0.0f32; 6];
        for tok in 24..32 {
            for (w, &x) in want.iter_mut().zip(seq.feat(&pool, 1, 0, tok)) {
                *w += x;
            }
        }
        for w in &mut want {
            *w /= 8.0;
        }
        for i in 0..6 {
            assert!((got[i] - want[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn scores_are_dot_products() {
        let (pool, seq) = build_seq(16);
        let mut idx = RadarIndex::new(4, 6);
        idx.maybe_restructure(&seq, &pool, 16);
        let q = vec![1.0f32, 0.0, 0.5, 0.0, 0.0, 2.0];
        let mut out = Vec::new();
        idx.scores(1, &q, &mut out);
        assert_eq!(out.len(), 4);
        let seg0 = idx.seg_feat(1, 0);
        let want: f32 = seg0.iter().zip(&q).map(|(a, b)| a * b).sum();
        assert!((out[0] - want).abs() < 1e-6);
    }

    #[test]
    fn top_k_correct_vs_sort() {
        let mut rng = SplitMix64::new(9);
        for _ in 0..50 {
            let n = 1 + rng.below(40) as usize;
            let k = 1 + rng.below(10) as usize;
            let scores: Vec<f32> =
                (0..n).map(|_| (rng.next_f64() * 10.0 - 5.0) as f32).collect();
            let mut got = top_k_indices(&scores, k);
            got.sort_unstable();
            let mut order: Vec<usize> = (0..n).collect();
            order.sort_by(|&a, &b| scores[b].total_cmp(&scores[a]));
            let mut want = order[..k.min(n)].to_vec();
            want.sort_unstable();
            // Compare score multisets (ties may pick different indices).
            let gs: Vec<f32> = got.iter().map(|&i| scores[i]).collect();
            let ws: Vec<f32> = want.iter().map(|&i| scores[i]).collect();
            let mut gs2 = gs.clone();
            let mut ws2 = ws.clone();
            gs2.sort_by(f32::total_cmp);
            ws2.sort_by(f32::total_cmp);
            assert_eq!(gs2, ws2, "scores {scores:?} k {k}");
        }
    }

    #[test]
    fn top_k_ties_break_by_lowest_index() {
        // Three-way tie at 1.0 and a two-way tie at 2.0: the winners are
        // fully determined — both 2.0s plus the *earliest* 1.0.
        let scores = vec![1.0f32, 2.0, 1.0, 2.0, 1.0];
        let mut got = top_k_indices(&scores, 3);
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 3]);
        // All-equal input: the first k indices, exactly.
        let flat = vec![0.5f32; 6];
        let mut got = top_k_indices(&flat, 4);
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 2, 3]);
        // Ties spanning the selection boundary with negatives.
        let scores = vec![-1.0f32, -1.0, -1.0, -2.0];
        let mut got = top_k_indices(&scores, 2);
        got.sort_unstable();
        assert_eq!(got, vec![0, 1]);
    }

    #[test]
    fn top_k_full_and_oversized_k() {
        let scores = vec![3.0f32, 1.0, 2.0];
        let mut got = top_k_indices(&scores, 3);
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 2]);
        let mut got = top_k_indices(&scores, 99);
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 2]);
        assert!(top_k_indices(&scores, 0).is_empty());
        assert!(top_k_indices(&[], 3).is_empty());
    }

    #[test]
    fn freeze_truncates_to_whole_segments() {
        let (pool, seq) = build_seq(64);
        let mut idx = RadarIndex::new(4, 6);
        idx.maybe_restructure(&seq, &pool, 64); // c=8, 8 segs
        // 50 tokens -> 6 whole segments (48 tokens).
        let f = idx.freeze(50).unwrap();
        assert_eq!((f.c, f.n_segs, f.boundary), (8, 6, 48));
        for p in 0..4 {
            for s in 0..6 {
                assert_eq!(f.seg_feat(p, s), idx.seg_feat(p, s));
            }
        }
        assert_eq!(f.bytes(), 4 * 6 * 6 * 4);
        // Fewer tokens than one segment -> nothing to freeze.
        assert!(idx.freeze(7).is_none());
        // Unstructured index -> nothing to freeze.
        assert!(RadarIndex::new(4, 6).freeze(64).is_none());
    }

    #[test]
    fn restructure_adopts_donor_segments_bitwise() {
        let (pool, seq) = build_seq(100);
        // Donor indexed the full 100 tokens at c=10.
        let mut donor_idx = RadarIndex::new(4, 6);
        donor_idx.maybe_restructure(&seq, &pool, 100);
        assert_eq!(donor_idx.c, 10);
        let frozen = donor_idx.freeze(80).unwrap(); // 8 segments
        // A fresh index restructuring at the same c adopts the shared
        // segments and recomputes the rest; result must be bit-identical
        // to a donor-free restructure.
        let mut warm = RadarIndex::new(4, 6);
        warm.maybe_restructure_with(&seq, &pool, 100, Some(&frozen));
        assert_eq!(warm.adopted_segs, 4 * 8);
        let mut cold = RadarIndex::new(4, 6);
        cold.maybe_restructure(&seq, &pool, 100);
        assert_eq!(cold.adopted_segs, 0);
        for p in 0..4 {
            for s in 0..10 {
                assert_eq!(
                    warm.seg_feat(p, s),
                    cold.seg_feat(p, s),
                    "plane {p} seg {s} diverged"
                );
            }
        }
    }

    #[test]
    fn restructure_ignores_mismatched_donor() {
        let (pool, seq) = build_seq(100);
        let mut donor_idx = RadarIndex::new(4, 6);
        donor_idx.maybe_restructure(&seq, &pool, 81); // c=9 — wrong geometry
        let frozen = donor_idx.freeze(81).unwrap();
        let mut idx = RadarIndex::new(4, 6);
        idx.maybe_restructure_with(&seq, &pool, 100, Some(&frozen));
        assert_eq!(idx.adopted_segs, 0, "c mismatch must disable adoption");
        assert_eq!(idx.c, 10);
        // Still correct despite the rejected donor.
        let mut cold = RadarIndex::new(4, 6);
        cold.maybe_restructure(&seq, &pool, 100);
        for p in 0..4 {
            assert_eq!(idx.seg_feat(p, 3), cold.seg_feat(p, 3));
        }
    }

    #[test]
    fn top_k_handles_negative_scores() {
        let scores = vec![-5.0f32, -1.0, -3.0];
        let mut got = top_k_indices(&scores, 2);
        got.sort_unstable();
        assert_eq!(got, vec![1, 2]);
    }

    #[test]
    fn exact_scores_monotone_in_alignment() {
        // A segment whose keys align with q must outscore an orthogonal one.
        let c = cfg();
        let mut pool = BlockPool::new(&c, 6, 100);
        let mut seq = SeqCache::new(6);
        let f = vec![0.0f32; 24];
        // 4 tokens aligned with q, then 4 anti-aligned.
        for i in 0..8 {
            let sign = if i < 4 { 1.0 } else { -1.0 };
            let k: Vec<f32> = (0..16).map(|_| sign).collect();
            seq.append(&mut pool, &k, &k, &f).unwrap();
        }
        let q = vec![1.0f32; 4];
        let mut out = Vec::new();
        exact_segment_scores(&seq, &pool, 0, 0, &q, 4, 2, &mut out);
        assert!(out[0] > out[1]);
    }

    #[test]
    fn prop_restructure_from_scratch_matches_incremental_state() {
        // Property: after any number of appends, a restructure at a
        // perfect square yields summaries equal to recomputing from the
        // raw features (which `summaries_equal_feature_means` checks for
        // one case); here we sweep random sizes.
        use crate::util::minitest::check;
        check(
            7,
            20,
            |r: &mut SplitMix64| 1 + r.below(12) as usize,
            |&root| {
                let t = root * root;
                let (pool, seq) = build_seq(t);
                let mut idx = RadarIndex::new(4, 6);
                idx.maybe_restructure(&seq, &pool, t);
                if idx.c != root || idx.n_segs != root {
                    return Err(format!("c={} n_segs={} want {root}", idx.c, idx.n_segs));
                }
                for p in 0..4 {
                    for s in 0..root {
                        let got = idx.seg_feat(p, s);
                        let (l, h) = (p / 2, p % 2);
                        let mut want = vec![0.0f32; 6];
                        for tok in s * root..(s + 1) * root {
                            for (w, &x) in want.iter_mut().zip(seq.feat(&pool, l, h, tok)) {
                                *w += x;
                            }
                        }
                        for i in 0..6 {
                            if (got[i] - want[i] / root as f32).abs() > 1e-4 {
                                return Err(format!("plane {p} seg {s} dim {i}"));
                            }
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
