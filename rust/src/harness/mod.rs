//! Experiment harnesses: one driver per paper table/figure
//! (DESIGN.md §6). Each prints the paper-style rows and writes CSV
//! into `results/`.

pub mod bench;
pub mod flagrate;
pub mod longbench;
pub mod ppl;
pub mod report;
pub mod theorem2;

use crate::config::{ArtifactPaths, PolicyKind, ServingConfig};
use crate::engine::Engine;
use crate::runtime::Runtime;
use anyhow::Result;
use std::sync::Arc;

/// Shared harness context: one loaded runtime, many engine configs.
pub struct Ctx {
    pub rt: Arc<Runtime>,
    pub paths: ArtifactPaths,
}

impl Ctx {
    pub fn load(root: &str, model: &str) -> Result<Self> {
        let paths = ArtifactPaths::new(root, model);
        let rt = Arc::new(Runtime::load(paths.clone())?);
        Ok(Self { rt, paths })
    }

    pub fn engine(&self, policy: PolicyKind, overrides: &[(&str, &str)]) -> Result<Engine> {
        let mut cfg = ServingConfig::default();
        cfg.policy = policy;
        for (k, v) in overrides {
            cfg.apply_override(k, v)?;
        }
        Engine::new(self.rt.clone(), cfg)
    }
}
