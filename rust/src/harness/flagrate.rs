//! Fig. 7 / §E: visualize the segment-attention approximation and
//! measure top-1 / top-3 flag rates of Radar vs recency vs random.
//!
//! Protocol (paper §E): 100 tokens after 1 sink token, 10 segments of
//! 10. For each query step we advance the hidden state through every
//! layer (full attention over the cache, so queries are the true
//! model queries) and, per (layer, head), compare:
//!   truth  = argmax of the *exact* segment attention mass,
//!   radar  = top-k of the Eq. 6 random-feature scores,
//!   recency= the most recent segments,
//!   random = uniform guesses.

use super::Ctx;
use crate::config::PolicyKind;
use crate::engine::GenRequest;
use crate::model::{embed, tokenizer};
use crate::radar::{exact_segment_scores, top_k_indices, RadarIndex};
use anyhow::Result;

pub struct FlagRates {
    pub strategy: &'static str,
    pub top1: f64,
    pub top3: f64,
}

pub struct Fig7Out {
    pub rates: Vec<FlagRates>,
    /// Per-layer radar rates (which layer hosts retrieval heads).
    pub per_layer: Vec<(usize, f64, f64)>,
    /// [steps][n_segs] exact and approx scores (layer 1 head 0 heatmap).
    pub exact_rows: Vec<Vec<f32>>,
    pub approx_rows: Vec<Vec<f32>>,
}

pub fn run(ctx: &Ctx, corpus: &[u8], n_queries: usize, n_feat: usize) -> Result<Fig7Out> {
    let rt = &ctx.rt;
    let mc = rt.config.clone();
    let total = 101usize; // 1 sink + 100 tokens, exactly 10 segments of 10
    let toks = tokenizer::encode_bytes(&corpus[..total + n_queries + 2]);
    let nf = n_feat.to_string();
    let mut engine = ctx.engine(PolicyKind::Vanilla, &[("n_feat", nf.as_str())])?;
    let req = GenRequest::teacher_forced(toks[..total + 1].to_vec(), toks[total + 1..].to_vec());
    let id = engine.add(req)?;
    // add() prefilled tokens [0, total); build the segment structure.
    let mut radar = RadarIndex::new(mc.n_lh(), n_feat);
    {
        let seq = engine.seq(id).unwrap();
        radar.force_restructure(&seq.cache, &engine.pool);
    }
    let (c, n_segs) = (radar.c, radar.n_segs);
    anyhow::ensure!((c, n_segs) == (10, 10), "paper setup: got c={c} segs={n_segs}");

    let qkv_meta = rt.registry.resolve_qkv(1, n_feat)?.clone();
    let am_meta = rt.registry.resolve_attn_mlp(1, 128)?.clone();
    let omega = rt.omega(n_feat)?;
    let (l_n, h_n, dh) = (mc.n_layers, mc.n_heads, mc.d_head);
    let s_bucket = am_meta.len;

    let mut hits1 = [0usize; 3];
    let mut hits3 = [0usize; 3];
    let mut layer_hits = vec![(0usize, 0usize, 0usize); l_n]; // (top1, top3, count)
    let mut n_total = 0usize;
    let mut rng = crate::util::prng::SplitMix64::new(5);
    let mut exact_rows = Vec::new();
    let mut approx_rows = Vec::new();

    // Full-attention selection: all cached tokens.
    let all: Vec<u32> = (0..total as u32).collect();
    let mut gk = vec![0.0f32; h_n * s_bucket * dh];
    let mut gv = vec![0.0f32; h_n * s_bucket * dh];
    let mut mask = vec![0.0f32; h_n * s_bucket];
    for qi in 0..n_queries {
        let tok = toks[total + qi];
        let pos = (total + qi) as i32;
        let mut x = embed(rt, &[tok]);
        for l in 0..l_n {
            let q_out = rt.qkv(&qkv_meta, l, &omega, &x, &[pos])?;
            for h in 0..h_n {
                let p = l * h_n + h;
                let phi_q = &q_out.phi_q[h * n_feat..(h + 1) * n_feat];
                let q = &q_out.q[h * dh..(h + 1) * dh];
                let mut approx = Vec::new();
                radar.scores(p, phi_q, &mut approx);
                let mut exact = Vec::new();
                {
                    let seq = engine.seq(id).unwrap();
                    exact_segment_scores(&seq.cache, &engine.pool, l, h, q, c, n_segs, &mut exact);
                }
                let truth = crate::model::argmax(&exact);
                let r1 = top_k_indices(&approx, 1);
                let r3 = top_k_indices(&approx, 3);
                hits1[0] += r1.contains(&truth) as usize;
                hits3[0] += r3.contains(&truth) as usize;
                layer_hits[l].0 += r1.contains(&truth) as usize;
                layer_hits[l].1 += r3.contains(&truth) as usize;
                layer_hits[l].2 += 1;
                hits1[1] += (truth == n_segs - 1) as usize;
                hits3[1] += (truth >= n_segs - 3) as usize;
                let rr1 = rng.sample_indices(n_segs, 1);
                let rr3 = rng.sample_indices(n_segs, 3);
                hits1[2] += rr1.contains(&truth) as usize;
                hits3[2] += rr3.contains(&truth) as usize;
                n_total += 1;
                if l == 1 && h == 0 && qi < 16 {
                    exact_rows.push(exact.clone());
                    approx_rows.push(approx.clone());
                }
            }
            // Advance x through layer l with full attention.
            {
                let seq = engine.seq(id).unwrap();
                for h in 0..h_n {
                    let koff = h * s_bucket * dh;
                    seq.cache.gather_plane(
                        &engine.pool, l, h, &all,
                        &mut gk[koff..koff + s_bucket * dh],
                        &mut gv[koff..koff + s_bucket * dh],
                    );
                    let mrow = &mut mask[h * s_bucket..(h + 1) * s_bucket];
                    mrow[..all.len()].fill(0.0);
                    mrow[all.len()..].fill(-1e30);
                }
            }
            let am = rt.attn_mlp(&am_meta, l, &x, &q_out.q, &q_out.k, &q_out.v, &gk, &gv, &mask)?;
            x = am.x;
        }
        // Feed the true next token into the cache via the engine.
        engine.step()?;
        {
            let seq = engine.seq(id).unwrap();
            if seq.done {
                break;
            }
        }
    }
    engine.remove(id);
    let pct = |x: usize| 100.0 * x as f64 / n_total as f64;
    Ok(Fig7Out {
        rates: vec![
            FlagRates { strategy: "radar", top1: pct(hits1[0]), top3: pct(hits3[0]) },
            FlagRates { strategy: "recency", top1: pct(hits1[1]), top3: pct(hits3[1]) },
            FlagRates { strategy: "random", top1: pct(hits1[2]), top3: pct(hits3[2]) },
        ],
        per_layer: layer_hits
            .iter()
            .enumerate()
            .map(|(l, &(h1, h3, n))| {
                (l, 100.0 * h1 as f64 / n.max(1) as f64, 100.0 * h3 as f64 / n.max(1) as f64)
            })
            .collect(),
        exact_rows,
        approx_rows,
    })
}

pub fn print(out: &Fig7Out, csv_path: &str) -> Result<()> {
    println!("\n== Fig 7 / §E: segment flag rates (10 segments, truth = exact argmax) ==");
    println!("{:<10} {:>8} {:>8}", "strategy", "top-1%", "top-3%");
    for r in &out.rates {
        println!("{:<10} {:>8.2} {:>8.2}", r.strategy, r.top1, r.top3);
    }
    println!("radar per layer (top-1%, top-3%):");
    for (l, t1, t3) in &out.per_layer {
        println!("  layer {l}: {t1:>6.2} {t3:>6.2}");
    }
    let mut csv = String::from("kind,step,seg0,seg1,seg2,seg3,seg4,seg5,seg6,seg7,seg8,seg9\n");
    for (i, row) in out.exact_rows.iter().enumerate() {
        csv.push_str(&format!("exact,{i}"));
        for v in row {
            csv.push_str(&format!(",{v:.5}"));
        }
        csv.push('\n');
    }
    for (i, row) in out.approx_rows.iter().enumerate() {
        csv.push_str(&format!("approx,{i}"));
        for v in row {
            csv.push_str(&format!(",{v:.5}"));
        }
        csv.push('\n');
    }
    std::fs::create_dir_all(std::path::Path::new(csv_path).parent().unwrap())?;
    crate::util::fsio::write_atomic(csv_path, csv.as_bytes())?;
    println!("(heatmap data -> {csv_path})");
    Ok(())
}
