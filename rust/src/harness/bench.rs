//! `bench` subcommand: synthetic long-context decode staging benchmark.
//!
//! Purely host-side: builds a paged KV cache filled with deterministic
//! PRNG values and drives the incremental staging arena through a
//! realistic decode selection schedule — attention sinks + steady top-k
//! segments + a sliding window, with periodic restructure churn — while
//! a force-full-restage arena runs in lockstep as the baseline. Every
//! step the two staged buffers are compared byte-for-byte, staged bytes
//! and staging time are accumulated, and the result is written to
//! `BENCH_decode.json`. No model artifacts are required, so the bench
//! runs anywhere (the CI smoke job included).

use crate::config::ModelConfig;
use crate::engine::staging::{
    stage_planes_serial, stage_planes_sharded, StageStats, StagedPlanes,
};
use crate::kvcache::{BlockPool, SeqCache, BLOCK_TOKENS};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::prng::SplitMix64;
use crate::util::threadpool::ThreadPool;
use anyhow::{ensure, Result};
use std::time::Instant;

const NEG: f32 = -1e30;

struct BenchCfg {
    t0: usize,
    steps: usize,
    layers: usize,
    heads: usize,
    d_head: usize,
    sinks: usize,
    window: usize,
    k_segs: usize,
    seg_len: usize,
    restructure_every: usize,
    workers: usize,
    seed: u64,
}

impl BenchCfg {
    fn from_args(args: &Args) -> Self {
        Self {
            t0: args.usize_or("t0", 2048),
            steps: args.usize_or("steps", 256),
            layers: args.usize_or("layers", 4),
            heads: args.usize_or("heads", 4),
            d_head: args.usize_or("dh", 64),
            sinks: args.usize_or("sinks", 4),
            window: args.usize_or("window", 256),
            k_segs: args.usize_or("k", 48),
            seg_len: args.usize_or("seg", 16),
            restructure_every: args.usize_or("restructure-every", 64),
            workers: args.usize_or("workers", 1),
            seed: args.usize_or("seed", 42) as u64,
        }
    }

    fn sel_len(&self) -> usize {
        self.sinks + self.k_segs * self.seg_len + self.window
    }
}

/// One plane's segment picks: starts on the `seg_len` grid inside
/// `[grid_base, grid_top)`, resampled wholesale at restructure steps
/// (mimicking Radar's perfect-square rebuilds).
fn sample_segments(rng: &mut SplitMix64, n_grid: usize, k: usize) -> Vec<usize> {
    let mut starts = rng.sample_indices(n_grid, k.min(n_grid));
    starts.sort_unstable();
    starts
}

/// Selection = sinks ++ segment tokens ++ window; the three regions are
/// disjoint and ordered, so the result is sorted + deduped by
/// construction (the policy invariant delta staging relies on).
fn build_selection(
    cfg: &BenchCfg,
    grid_base: usize,
    seg_starts: &[usize],
    t: usize,
) -> Vec<u32> {
    let mut sel = Vec::with_capacity(cfg.sel_len());
    for i in 0..cfg.sinks {
        sel.push(i as u32);
    }
    for &g in seg_starts {
        let start = grid_base + g * cfg.seg_len;
        for tok in start..start + cfg.seg_len {
            sel.push(tok as u32);
        }
    }
    for tok in t.saturating_sub(cfg.window)..t {
        sel.push(tok as u32);
    }
    sel
}

/// Append one synthetic token (PRNG K/V, zero features) to the cache.
fn append_token(
    rng: &mut SplitMix64,
    pool: &mut BlockPool,
    cache: &mut SeqCache,
    lh: usize,
    dh: usize,
    n_feat: usize,
) -> Result<()> {
    let k: Vec<f32> = (0..lh * dh).map(|_| rng.next_f32()).collect();
    let v: Vec<f32> = (0..lh * dh).map(|_| rng.next_f32()).collect();
    let f = vec![0.0f32; lh * n_feat];
    cache.append(pool, &k, &v, &f)?;
    Ok(())
}

pub fn run(args: &Args, out: &str) -> Result<()> {
    let cfg = BenchCfg::from_args(args);
    let lh = cfg.layers * cfg.heads;
    let n_feat = 8usize;
    ensure!(cfg.t0 > cfg.window + cfg.sinks, "--t0 must exceed --window + --sinks");
    let grid_base = cfg.sinks.max(BLOCK_TOKENS);
    let grid_top = cfg.t0.saturating_sub(cfg.window + cfg.seg_len);
    let n_grid = grid_top.saturating_sub(grid_base) / cfg.seg_len;
    ensure!(
        n_grid >= cfg.k_segs,
        "context too small for k={} segments of {} tokens (grid has {n_grid})",
        cfg.k_segs,
        cfg.seg_len
    );

    let mc = ModelConfig {
        name: "bench".into(),
        d_model: cfg.heads * cfg.d_head,
        n_layers: cfg.layers,
        n_heads: cfg.heads,
        d_head: cfg.d_head,
        d_ffn: 4 * cfg.heads * cfg.d_head,
        n_feat,
        max_train_len: cfg.t0 + cfg.steps,
        vocab: 256,
    };
    let blocks = (cfg.t0 + cfg.steps).div_ceil(BLOCK_TOKENS) + 4;
    let mut pool = BlockPool::new(&mc, n_feat, blocks);
    let mut cache = SeqCache::new(n_feat);
    let mut rng = SplitMix64::new(cfg.seed);
    crate::info!(
        "bench: growing synthetic cache to t0={} ({} planes, dh={})",
        cfg.t0,
        lh,
        cfg.d_head
    );
    for _ in 0..cfg.t0 {
        append_token(&mut rng, &mut pool, &mut cache, lh, cfg.d_head, n_feat)?;
    }

    // Per-plane steady top-k segment picks.
    let mut seg_starts: Vec<Vec<usize>> =
        (0..lh).map(|_| sample_segments(&mut rng, n_grid, cfg.k_segs)).collect();
    let tp = (cfg.workers > 1).then(|| ThreadPool::new(cfg.workers, "bench-stage"));

    // Dispatch buffers: a fixed S bucket holding the whole selection.
    let s = cfg.sel_len().next_multiple_of(64);
    let row = lh * s * cfg.d_head;
    let mut dk_d = vec![0.0f32; row];
    let mut dv_d = vec![0.0f32; row];
    let mut dm_d = vec![0.0f32; lh * s];
    let (mut dk_f, mut dv_f) = (dk_d.clone(), dv_d.clone());
    let mut dm_f = dm_d.clone();

    let mut delta_arena = StagedPlanes::new(lh);
    let mut full_arena = StagedPlanes::new(lh);
    let mut delta_stats = StageStats::default();
    let mut full_stats = StageStats::default();
    let (mut delta_secs, mut full_secs) = (0f64, 0f64);

    let t_bench = Instant::now();
    for step in 0..cfg.steps {
        let t = cache.len();
        if cfg.restructure_every > 0 && step > 0 && step % cfg.restructure_every == 0 {
            // Restructure churn: every plane resamples its top-k set,
            // the delta path degrades to (mostly) full gathers this step.
            for sgs in &mut seg_starts {
                *sgs = sample_segments(&mut rng, n_grid, cfg.k_segs);
            }
        }
        let per_plane: Vec<Vec<u32>> =
            seg_starts.iter().map(|sgs| build_selection(&cfg, grid_base, sgs, t)).collect();

        let t0 = Instant::now();
        let st = match &tp {
            Some(tp) => stage_planes_sharded(
                tp, cfg.workers, &mut delta_arena.planes, 0, cfg.heads, &cache, &pool,
                &per_plane, s, &mut dk_d, &mut dv_d, &mut dm_d, true, NEG,
            ),
            None => stage_planes_serial(
                &mut delta_arena.planes, 0, cfg.heads, &cache, &pool, &per_plane, s,
                &mut dk_d, &mut dv_d, &mut dm_d, true, NEG,
            ),
        };
        delta_secs += t0.elapsed().as_secs_f64();
        delta_stats.merge(&st);

        let t1 = Instant::now();
        let st = stage_planes_serial(
            &mut full_arena.planes, 0, cfg.heads, &cache, &pool, &per_plane, s,
            &mut dk_f, &mut dv_f, &mut dm_f, false, NEG,
        );
        full_secs += t1.elapsed().as_secs_f64();
        full_stats.merge(&st);

        ensure!(dk_d == dk_f, "staged K diverged from full re-gather at step {step}");
        ensure!(dv_d == dv_f, "staged V diverged from full re-gather at step {step}");
        ensure!(dm_d == dm_f, "staged mask diverged from full re-gather at step {step}");

        append_token(&mut rng, &mut pool, &mut cache, lh, cfg.d_head, n_feat)?;
    }
    let wall_secs = t_bench.elapsed().as_secs_f64();
    debug_assert_eq!(full_stats.delta_hits, 0, "force-full path must never count hits");
    debug_assert_eq!(full_stats.bytes_delta, full_stats.bytes_full);

    let steps = cfg.steps as f64;
    let hit_denom = (delta_stats.delta_hits + delta_stats.full_restages).max(1);
    let delta_hit_ratio = delta_stats.delta_hits as f64 / hit_denom as f64;
    let reduction = delta_stats.bytes_full as f64 / (delta_stats.bytes_delta.max(1)) as f64;
    let stage_ms_delta = delta_secs * 1e3 / steps;
    let stage_ms_full = full_secs * 1e3 / steps;
    let tokens_per_sec = steps / delta_secs.max(1e-12);

    let report = Json::obj()
        .with("bench", "decode_staging")
        .with("engine_dispatch", false)
        .with("t0", cfg.t0)
        .with("steps", cfg.steps)
        .with("layers", cfg.layers)
        .with("heads", cfg.heads)
        .with("d_head", cfg.d_head)
        .with("sel_per_plane", cfg.sel_len())
        .with("s_bucket", s)
        .with("window", cfg.window)
        .with("k_segments", cfg.k_segs)
        .with("seg_len", cfg.seg_len)
        .with("restructure_every", cfg.restructure_every)
        .with("stage_workers", cfg.workers)
        .with("seed", cfg.seed as usize)
        .with("tokens_per_sec", tokens_per_sec)
        .with("stage_ms", stage_ms_delta)
        .with("stage_ms_full", stage_ms_full)
        .with("dispatch_ms", 0.0)
        .with("wall_secs", wall_secs)
        .with("staged_bytes_full", delta_stats.bytes_full as f64)
        .with("staged_bytes_delta", delta_stats.bytes_delta as f64)
        .with("bytes_per_step_full", delta_stats.bytes_full as f64 / steps)
        .with("bytes_per_step_delta", delta_stats.bytes_delta as f64 / steps)
        .with("bytes_reduction", reduction)
        .with("stage_delta_hits", delta_stats.delta_hits as f64)
        .with("stage_full_restages", delta_stats.full_restages as f64)
        .with("delta_hit_ratio", delta_hit_ratio)
        .with("byte_identical", true);
    std::fs::create_dir_all(out)?;
    let path = format!("{out}/BENCH_decode.json");
    // Write-temp-then-rename: a crash mid-write can't leave a torn
    // report behind for downstream tooling to choke on.
    crate::util::fsio::write_atomic(&path, report.to_string().as_bytes())?;

    println!("decode staging bench (synthetic, host-side)");
    println!(
        "  t0={} steps={} planes={} sel/plane={} S={} workers={}",
        cfg.t0,
        cfg.steps,
        lh,
        cfg.sel_len(),
        s,
        cfg.workers
    );
    println!(
        "  stage: {:.3} ms/step delta vs {:.3} ms/step full ({:.1} tok/s staged)",
        stage_ms_delta, stage_ms_full, tokens_per_sec
    );
    println!(
        "  bytes/step: {:.0} delta vs {:.0} full ({reduction:.1}x reduction, hit ratio {:.3})",
        delta_stats.bytes_delta as f64 / steps,
        delta_stats.bytes_full as f64 / steps,
        delta_hit_ratio
    );
    println!("  wrote {path}");
    Ok(())
}
