//! Theorem 2 Monte-Carlo: empirical probability that Eq. 6 identifies
//! the arg-top segment, as a function of the projection dimension n
//! and the attention gap — overlaid with the theorem's sufficient
//! condition. Pure rust (no artifacts): the math is Eq. 4/5/6 exactly.

use crate::util::prng::SplitMix64;
use anyhow::Result;

/// phi_Omega(k) with Omega ~ N(0,1)^{n x d} (Eq. 4), k' = k / d^(1/4).
fn phi(k: &[f32], omega: &[f32], n: usize) -> Vec<f32> {
    let d = k.len();
    let scale = 1.0 / (d as f32).sqrt().sqrt();
    let kp: Vec<f32> = k.iter().map(|x| x * scale).collect();
    let sq: f32 = 0.5 * kp.iter().map(|x| x * x).sum::<f32>();
    let inv_sqrt_n = 1.0 / (n as f32).sqrt();
    (0..n)
        .map(|i| {
            let row = &omega[i * d..(i + 1) * d];
            let dot: f32 = row.iter().zip(&kp).map(|(a, b)| a * b).sum();
            (dot - sq).exp() * inv_sqrt_n
        })
        .collect()
}

pub struct Thm2Point {
    pub n: usize,
    pub gap: f64,
    pub success_rate: f64,
    /// Gap the theorem requires for delta = 0.1 at this n.
    pub required_gap: f64,
}

/// One trial: `n_segs` segments of `c` keys in R^d; segment 0's keys are
/// biased towards the query direction by `bias` so it holds the top
/// attention mass; success = Eq. 6 ranks segment 0 first.
fn trial(rng: &mut SplitMix64, d: usize, c: usize, n_segs: usize, n: usize, bias: f32) -> (bool, f64) {
    let q: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32 * 0.5).collect();
    let qn: f32 = q.iter().map(|x| x * x).sum::<f32>();
    let omega: Vec<f32> = (0..n * d).map(|_| rng.next_gaussian() as f32).collect();
    let phi_q = phi(&q, &omega, n);
    let mut seg_scores_exact = Vec::with_capacity(n_segs);
    let mut seg_scores_approx = Vec::with_capacity(n_segs);
    let scale = 1.0 / (d as f32).sqrt();
    for s in 0..n_segs {
        let mut exact = 0.0f64;
        let mut feat = vec![0.0f32; n];
        for _ in 0..c {
            let mut k: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32 * 0.5).collect();
            if s == 0 {
                for (ki, qi) in k.iter_mut().zip(&q) {
                    *ki += bias * qi / qn.sqrt().max(1e-6);
                }
            }
            let dot: f32 = k.iter().zip(&q).map(|(a, b)| a * b).sum();
            exact += ((dot * scale) as f64).exp();
            for (f, p) in feat.iter_mut().zip(phi(&k, &omega, n)) {
                *f += p;
            }
        }
        let approx: f32 = feat.iter().zip(&phi_q).map(|(a, b)| a * b).sum::<f32>() / c as f32;
        seg_scores_exact.push(exact / c as f64);
        seg_scores_approx.push(approx);
    }
    // Normalized attention gap between top (seg 0 by construction,
    // verify) and runner-up.
    let top = crate::model::argmax(&seg_scores_approx.iter().map(|&x| x).collect::<Vec<f32>>());
    let mut exact_sorted = seg_scores_exact.clone();
    exact_sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let denom: f64 = seg_scores_exact.iter().sum::<f64>() * n_segs as f64;
    let gap = (exact_sorted[0] - exact_sorted[1]) / denom.max(1e-12);
    let truth = seg_scores_exact
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0;
    (top == truth, gap)
}

/// Sweep n; report empirical success rate + the theorem's required gap.
pub fn run(trials: usize, seed: u64) -> Result<Vec<Thm2Point>> {
    let (d, c, n_segs, bias) = (32usize, 8usize, 8usize, 1.2f32);
    let zeta: f64 = 1.5; // approximate max norm under the 0.5-scaled gaussians
    let delta = 0.1f64;
    let mut out = Vec::new();
    for &n in &[8usize, 16, 32, 64, 128, 256, 512] {
        let mut rng = SplitMix64::new(seed ^ n as u64);
        let mut ok = 0usize;
        let mut gap_sum = 0.0;
        for _ in 0..trials {
            let (success, gap) = trial(&mut rng, d, c, n_segs, n, bias);
            ok += success as usize;
            gap_sum += gap;
        }
        // Theorem 2 sufficient gap: (1/c) exp(zeta^2/sqrt(d)) sqrt(8 log(2(c-1)/delta) / n)
        let required = (1.0 / c as f64)
            * (zeta * zeta / (d as f64).sqrt()).exp()
            * (8.0 * (2.0 * (c as f64 - 1.0) / delta).ln() / n as f64).sqrt();
        out.push(Thm2Point {
            n,
            gap: gap_sum / trials as f64,
            success_rate: ok as f64 / trials as f64,
            required_gap: required,
        });
    }
    Ok(out)
}

pub fn print(points: &[Thm2Point], csv_path: &str) -> Result<()> {
    println!("\n== Theorem 2 Monte-Carlo: top-segment identification vs n ==");
    println!(
        "{:>6} {:>14} {:>14} {:>16}",
        "n", "success rate", "observed gap", "thm2 gap (d=.1)"
    );
    for p in points {
        println!(
            "{:>6} {:>14.3} {:>14.5} {:>16.5}",
            p.n, p.success_rate, p.gap, p.required_gap
        );
    }
    let mut csv = String::from("n,success_rate,observed_gap,required_gap\n");
    for p in points {
        csv.push_str(&format!(
            "{},{:.5},{:.6},{:.6}\n",
            p.n, p.success_rate, p.gap, p.required_gap
        ));
    }
    std::fs::create_dir_all(std::path::Path::new(csv_path).parent().unwrap())?;
    crate::util::fsio::write_atomic(csv_path, csv.as_bytes())?;
    println!("(data -> {csv_path})");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phi_unbiased_kernel_estimate() {
        // E[phi(q).phi(k)] ~= exp(q.k/sqrt(d)) for large n.
        let mut rng = SplitMix64::new(1);
        let d = 16;
        let q: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32 * 0.4).collect();
        let k: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32 * 0.4).collect();
        let n = 16384;
        let omega: Vec<f32> = (0..n * d).map(|_| rng.next_gaussian() as f32).collect();
        let est: f32 = phi(&q, &omega, n).iter().zip(phi(&k, &omega, n)).map(|(a, b)| a * b).sum();
        let dot: f32 = q.iter().zip(&k).map(|(a, b)| a * b).sum();
        let exact = (dot / (d as f32).sqrt()).exp();
        assert!(
            (est - exact).abs() / exact < 0.2,
            "est {est} vs exact {exact}"
        );
    }

    #[test]
    fn success_rate_increases_with_n() {
        let points = run(40, 3).unwrap();
        let first = points.first().unwrap().success_rate;
        let last = points.last().unwrap().success_rate;
        assert!(
            last >= first,
            "success should not degrade with larger n: {first} -> {last}"
        );
        assert!(last > 0.8, "large-n success should be high: {last}");
    }
}
