//! Report helpers shared by harness drivers.

use crate::metrics::Metrics;
use anyhow::Result;
use std::path::Path;

/// Append a markdown section to EXPERIMENTS-style logs.
pub fn append_section(path: &str, title: &str, body: &str) -> Result<()> {
    use std::io::Write;
    if let Some(parent) = Path::new(path).parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
    writeln!(f, "\n## {title}\n\n{body}")?;
    Ok(())
}

/// Simple fixed-width markdown table.
pub fn md_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut s = String::new();
    s.push('|');
    for h in headers {
        s.push_str(&format!(" {h} |"));
    }
    s.push_str("\n|");
    for _ in headers {
        s.push_str("---|");
    }
    s.push('\n');
    for r in rows {
        s.push('|');
        for c in r {
            s.push_str(&format!(" {c} |"));
        }
        s.push('\n');
    }
    s
}

/// One-line shared-prefix cache summary for run reports: hit rate,
/// total prefill tokens skipped, and currently shared blocks.
pub fn prefix_cache_summary(m: &Metrics) -> String {
    let hits = m.counter("prefix_hits");
    let misses = m.counter("prefix_misses");
    let probes = hits + misses;
    let rate = if probes == 0 { 0.0 } else { hits as f64 / probes as f64 * 100.0 };
    let n = m.histogram_count("prefill_tokens_saved");
    let saved = if n == 0 { 0.0 } else { m.histogram_mean("prefill_tokens_saved") * n as f64 };
    format!(
        "prefix cache: {hits}/{probes} hits ({rate:.0}%), {saved:.0} prefill tokens saved, \
         {} shared blocks",
        m.gauge("prefix_shared_blocks") as u64
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_shape() {
        let t = md_table(&["a", "b"], &[vec!["1".into(), "2".into()]]);
        assert!(t.contains("| a | b |"));
        assert!(t.contains("| 1 | 2 |"));
    }

    #[test]
    fn prefix_summary_shapes() {
        let m = Metrics::new();
        assert!(prefix_cache_summary(&m).contains("0/0 hits (0%)"));
        m.add("prefix_hits", 3);
        m.inc("prefix_misses");
        m.observe("prefill_tokens_saved", 64.0);
        m.observe("prefill_tokens_saved", 32.0);
        m.set_gauge("prefix_shared_blocks", 4.0);
        let s = prefix_cache_summary(&m);
        assert!(s.contains("3/4 hits (75%)"), "{s}");
        assert!(s.contains("96 prefill tokens saved"), "{s}");
        assert!(s.contains("4 shared blocks"), "{s}");
    }
}
