//! Report helpers shared by harness drivers.

use anyhow::Result;
use std::path::Path;

/// Append a markdown section to EXPERIMENTS-style logs.
pub fn append_section(path: &str, title: &str, body: &str) -> Result<()> {
    use std::io::Write;
    if let Some(parent) = Path::new(path).parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
    writeln!(f, "\n## {title}\n\n{body}")?;
    Ok(())
}

/// Simple fixed-width markdown table.
pub fn md_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut s = String::new();
    s.push('|');
    for h in headers {
        s.push_str(&format!(" {h} |"));
    }
    s.push_str("\n|");
    for _ in headers {
        s.push_str("---|");
    }
    s.push('\n');
    for r in rows {
        s.push('|');
        for c in r {
            s.push_str(&format!(" {c} |"));
        }
        s.push('\n');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_shape() {
        let t = md_table(&["a", "b"], &[vec!["1".into(), "2".into()]]);
        assert!(t.contains("| a | b |"));
        assert!(t.contains("| 1 | 2 |"));
    }
}
