//! Report helpers shared by harness drivers.

use crate::metrics::Metrics;
use anyhow::Result;
use std::path::Path;

/// Append a markdown section to EXPERIMENTS-style logs.
pub fn append_section(path: &str, title: &str, body: &str) -> Result<()> {
    use std::io::Write;
    if let Some(parent) = Path::new(path).parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
    writeln!(f, "\n## {title}\n\n{body}")?;
    Ok(())
}

/// Simple fixed-width markdown table.
pub fn md_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut s = String::new();
    s.push('|');
    for h in headers {
        s.push_str(&format!(" {h} |"));
    }
    s.push_str("\n|");
    for _ in headers {
        s.push_str("---|");
    }
    s.push('\n');
    for r in rows {
        s.push('|');
        for c in r {
            s.push_str(&format!(" {c} |"));
        }
        s.push('\n');
    }
    s
}

/// One-line shared-prefix cache summary for run reports: hit rate,
/// total prefill tokens skipped, and currently shared blocks.
pub fn prefix_cache_summary(m: &Metrics) -> String {
    let hits = m.counter("prefix_hits");
    let misses = m.counter("prefix_misses");
    let probes = hits + misses;
    let rate = if probes == 0 { 0.0 } else { hits as f64 / probes as f64 * 100.0 };
    let n = m.histogram_count("prefill_tokens_saved");
    let saved = if n == 0 { 0.0 } else { m.histogram_mean("prefill_tokens_saved") * n as f64 };
    format!(
        "prefix cache: {hits}/{probes} hits ({rate:.0}%), {saved:.0} prefill tokens saved, \
         {} shared blocks",
        m.gauge("prefix_shared_blocks") as u64
    )
}

/// One-line robustness summary for run reports: contained per-sequence
/// errors, KV-pressure preemptions (with mean re-prefill recovery
/// latency when any completed), and deadline timeouts.
pub fn robustness_summary(m: &Metrics) -> String {
    let contained = m.counter("contained_errors");
    let preemptions = m.counter("preemptions");
    let timeouts = m.counter("timeouts");
    let recovery = if m.latency_count("preempt_recovery") == 0 {
        String::new()
    } else {
        format!(" (mean recovery {:.1} ms)", m.latency_mean_us("preempt_recovery") / 1e3)
    };
    let mut s = format!(
        "robustness: {contained} contained errors, {preemptions} preemptions{recovery}, \
         {timeouts} timeouts"
    );
    // Overload/degradation counters only appear when something fired,
    // so quiet runs keep the short historical line.
    for (name, label) in [
        ("shed_requests", "shed"),
        ("watchdog_trips", "watchdog trips"),
        ("anomaly_fallbacks", "anomaly fallbacks"),
        ("degraded_mode_entered", "degraded-mode entries"),
    ] {
        let n = m.counter(name);
        if n > 0 {
            s.push_str(&format!(", {n} {label}"));
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_shape() {
        let t = md_table(&["a", "b"], &[vec!["1".into(), "2".into()]]);
        assert!(t.contains("| a | b |"));
        assert!(t.contains("| 1 | 2 |"));
    }

    #[test]
    fn prefix_summary_shapes() {
        let m = Metrics::new();
        assert!(prefix_cache_summary(&m).contains("0/0 hits (0%)"));
        m.add("prefix_hits", 3);
        m.inc("prefix_misses");
        m.observe("prefill_tokens_saved", 64.0);
        m.observe("prefill_tokens_saved", 32.0);
        m.set_gauge("prefix_shared_blocks", 4.0);
        let s = prefix_cache_summary(&m);
        assert!(s.contains("3/4 hits (75%)"), "{s}");
        assert!(s.contains("96 prefill tokens saved"), "{s}");
        assert!(s.contains("4 shared blocks"), "{s}");
    }

    #[test]
    fn robustness_summary_shapes() {
        let m = Metrics::new();
        let s = robustness_summary(&m);
        assert!(s.contains("0 contained errors, 0 preemptions, 0 timeouts"), "{s}");
        m.inc("contained_errors");
        m.add("preemptions", 2);
        m.inc("timeouts");
        m.observe_us("preempt_recovery", 1500.0);
        m.observe_us("preempt_recovery", 2500.0);
        let s = robustness_summary(&m);
        assert!(s.contains("1 contained errors"), "{s}");
        assert!(s.contains("2 preemptions (mean recovery 2.0 ms)"), "{s}");
        assert!(s.contains("1 timeouts"), "{s}");
    }

    #[test]
    fn robustness_summary_appends_overload_counters_only_when_nonzero() {
        let m = Metrics::new();
        let quiet = robustness_summary(&m);
        assert!(!quiet.contains("shed"), "{quiet}");
        assert!(!quiet.contains("watchdog"), "{quiet}");
        m.add("shed_requests", 3);
        m.inc("watchdog_trips");
        m.add("anomaly_fallbacks", 2);
        m.inc("degraded_mode_entered");
        let s = robustness_summary(&m);
        assert!(s.contains("3 shed"), "{s}");
        assert!(s.contains("1 watchdog trips"), "{s}");
        assert!(s.contains("2 anomaly fallbacks"), "{s}");
        assert!(s.contains("1 degraded-mode entries"), "{s}");
    }
}
