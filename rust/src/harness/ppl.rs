//! Teacher-forced perplexity + elapsed-time curves (Fig. 2 / 3 / 4 /
//! 5 / 6 all reduce to this driver with different policies/params).

use super::Ctx;
use crate::config::PolicyKind;
use crate::engine::{GenRequest, SessionEvent};
use crate::model::tokenizer;
use anyhow::Result;
use std::time::Instant;

#[derive(Debug, Clone)]
pub struct PplPoint {
    /// Context length t at this sample.
    pub t: usize,
    /// Cumulative perplexity over evaluated tokens so far.
    pub ppl: f64,
    /// Cumulative decode wallclock seconds.
    pub elapsed_s: f64,
    /// Tokens/s over the last interval.
    pub throughput: f64,
}

#[derive(Debug, Clone)]
pub struct PplCurve {
    pub policy: String,
    pub points: Vec<PplPoint>,
    pub final_ppl: f64,
    pub total_s: f64,
}

/// Evaluate `policy` on `corpus[0..eval_len]`: prefill the first
/// `prefill` tokens, then teacher-force the rest, sampling a curve
/// point every `every` tokens.
pub fn ppl_curve(
    ctx: &Ctx,
    policy: PolicyKind,
    overrides: &[(&str, &str)],
    corpus: &[u8],
    prefill: usize,
    eval_len: usize,
    every: usize,
) -> Result<PplCurve> {
    let eval_len = eval_len.min(corpus.len());
    assert!(prefill < eval_len, "prefill {prefill} >= eval {eval_len}");
    let mut engine = ctx.engine(policy, overrides)?;
    let toks = tokenizer::encode_bytes(&corpus[..eval_len]);
    let prompt: Vec<i32> = toks[..prefill.max(1)].to_vec();
    let teacher: Vec<i32> = toks[prefill.max(1)..].to_vec();
    let prompt_len = prompt.len();
    let req = GenRequest::teacher_forced(prompt, teacher);
    // Session stream: the engine pushes per-token events; we step the
    // engine ourselves and drain the handle between steps.
    let handle = engine.submit(req)?;
    // Admission + prefill happen inside this first (untimed) step, so
    // `elapsed` stays a pure-decode clock like the pre-session code
    // (which prefilled inside `add`, outside the timed loop).
    engine.step()?;
    let mut points = Vec::new();
    let mut nll_sum = 0.0f64;
    let mut n_eval = 0usize;
    let mut elapsed = 0.0f64;
    let mut last_mark = Instant::now();
    let mut last_count = 0usize;
    let mut finished = false;
    loop {
        while let Some(ev) = handle.try_recv() {
            match ev {
                SessionEvent::Token { logprob, .. } => {
                    nll_sum -= logprob;
                    n_eval += 1;
                }
                SessionEvent::Done { .. } => finished = true,
                SessionEvent::Error(e) => anyhow::bail!("ppl session failed: {e}"),
            }
        }
        if n_eval > last_count && (n_eval - last_count >= every || finished) {
            let dt = last_mark.elapsed().as_secs_f64();
            let tp = (n_eval - last_count) as f64 / dt.max(1e-9);
            points.push(PplPoint {
                // Context length: prefill covers prompt_len - 1
                // positions, each evaluated token appends one more.
                t: prompt_len.saturating_sub(1) + n_eval,
                ppl: (nll_sum / n_eval as f64).exp(),
                elapsed_s: elapsed,
                throughput: tp,
            });
            last_mark = Instant::now();
            last_count = n_eval;
        }
        if engine.idle() {
            break;
        }
        let t0 = Instant::now();
        engine.step()?;
        elapsed += t0.elapsed().as_secs_f64();
    }
    let final_ppl =
        if n_eval == 0 { f64::NAN } else { (nll_sum / n_eval as f64).exp() };
    Ok(PplCurve {
        policy: format!("{}{}", policy.name(), fmt_overrides(overrides)),
        points,
        final_ppl,
        total_s: elapsed,
    })
}

fn fmt_overrides(ov: &[(&str, &str)]) -> String {
    if ov.is_empty() {
        String::new()
    } else {
        let s: Vec<String> = ov.iter().map(|(k, v)| format!("{k}={v}")).collect();
        format!("[{}]", s.join(","))
    }
}

/// Print a set of curves as aligned columns + dump CSV.
pub fn print_curves(title: &str, curves: &[PplCurve], csv_path: &str) -> Result<()> {
    println!("\n== {title} ==");
    println!(
        "{:<28} {:>10} {:>12} {:>12}",
        "policy", "final PPL", "total s", "tok/s (end)"
    );
    for c in curves {
        let tp = c.points.last().map(|p| p.throughput).unwrap_or(f64::NAN);
        println!(
            "{:<28} {:>10.3} {:>12.2} {:>12.1}",
            c.policy, c.final_ppl, c.total_s, tp
        );
    }
    let mut csv = String::from("policy,t,ppl,elapsed_s,throughput\n");
    for c in curves {
        for p in &c.points {
            csv.push_str(&format!(
                "{},{},{:.5},{:.4},{:.2}\n",
                c.policy, p.t, p.ppl, p.elapsed_s, p.throughput
            ));
        }
    }
    std::fs::create_dir_all(std::path::Path::new(csv_path).parent().unwrap())?;
    crate::util::fsio::write_atomic(csv_path, csv.as_bytes())?;
    println!("(curve data -> {csv_path})");
    Ok(())
}
