//! LongBench-S end-to-end evaluation (Table 1): every method x every
//! subtask, greedy generation, per-task metrics, average score and
//! average percentile.

use super::Ctx;
use crate::config::PolicyKind;
use crate::engine::GenRequest;
use crate::model::tokenizer;
use crate::workload::score::percentile_ranks;
use crate::workload::tasks::{generate, TASKS};
use anyhow::Result;

#[derive(Debug, Clone)]
pub struct LongBenchRow {
    pub method: String,
    pub per_task: Vec<f64>,
    pub avg_score: f64,
    pub avg_percentile: f64,
}

/// Run one method over all 16 tasks (n instances each).
fn eval_method(
    ctx: &Ctx,
    policy: PolicyKind,
    overrides: &[(&str, &str)],
    ctx_len: usize,
    instances: usize,
) -> Result<Vec<f64>> {
    let mut per_task = Vec::with_capacity(TASKS.len());
    for spec in &TASKS {
        let mut total = 0.0;
        for i in 0..instances {
            let inst = generate(spec, ctx_len, 1000 + i as u64);
            let mut engine = ctx.engine(policy, overrides)?;
            let prompt = tokenizer::encode_bytes(&inst.prompt);
            let mut req = GenRequest::new(prompt, inst.max_new_tokens);
            req.stop_token = Some(b' ' as i32);
            // Session stream: drive the engine, then drain the handle
            // (the terminal Done closes the channel, so this can't block).
            let handle = engine.submit(req)?;
            while !engine.idle() {
                engine.step()?;
            }
            let out = handle.collect();
            if let Some(e) = out.error {
                anyhow::bail!("longbench session failed: {e}");
            }
            let pred = tokenizer::decode(&out.tokens);
            total += spec.metric.score(pred.trim(), &inst.reference);
        }
        per_task.push(100.0 * total / instances as f64);
    }
    Ok(per_task)
}

/// The Table-1 driver: vanilla (full context) + every budgeted method
/// at the given n_c.
pub fn run_table(
    ctx: &Ctx,
    ctx_len: usize,
    n_c: usize,
    instances: usize,
    methods: &[PolicyKind],
) -> Result<Vec<LongBenchRow>> {
    let nc = n_c.to_string();
    let mut rows = Vec::new();
    for &m in methods {
        let overrides: Vec<(&str, &str)> = match m {
            PolicyKind::Vanilla => vec![],
            // paper: sliding window 32 + n_c middle tokens
            _ => vec![("window", "32"), ("budget", nc.as_str())],
        };
        let per_task = eval_method(ctx, m, &overrides, ctx_len, instances)?;
        let avg = per_task.iter().sum::<f64>() / per_task.len() as f64;
        rows.push(LongBenchRow {
            method: m.name().to_string(),
            per_task,
            avg_score: avg,
            avg_percentile: 0.0,
        });
        crate::info!("longbench: {} done (avg {:.2})", m.name(), avg);
    }
    // Percentiles across methods per task.
    let task_rows: Vec<Vec<f64>> = (0..TASKS.len())
        .map(|t| rows.iter().map(|r| r.per_task[t]).collect())
        .collect();
    let percs = percentile_ranks(&task_rows);
    for (r, p) in rows.iter_mut().zip(percs) {
        r.avg_percentile = p;
    }
    Ok(rows)
}

pub fn print_table(title: &str, rows: &[LongBenchRow], csv_path: &str) -> Result<()> {
    println!("\n== {title} ==");
    print!("{:<14}", "method");
    for spec in &TASKS {
        print!(" {:>9}", &spec.name[..spec.name.len().min(9)]);
    }
    println!(" {:>9} {:>9}", "AvgScore", "AvgPerc");
    for r in rows {
        print!("{:<14}", r.method);
        for s in &r.per_task {
            print!(" {:>9.2}", s);
        }
        println!(" {:>9.2} {:>9.2}", r.avg_score, r.avg_percentile);
    }
    let mut csv = String::from("method");
    for spec in &TASKS {
        csv.push_str(&format!(",{}", spec.name));
    }
    csv.push_str(",avg_score,avg_percentile\n");
    for r in rows {
        csv.push_str(&r.method);
        for s in &r.per_task {
            csv.push_str(&format!(",{s:.3}"));
        }
        csv.push_str(&format!(",{:.3},{:.3}\n", r.avg_score, r.avg_percentile));
    }
    std::fs::create_dir_all(std::path::Path::new(csv_path).parent().unwrap())?;
    crate::util::fsio::write_atomic(csv_path, csv.as_bytes())?;
    println!("(table data -> {csv_path})");
    Ok(())
}
