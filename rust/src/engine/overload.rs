//! Overload control: admission token bucket, load-shed victim
//! selection, the anomaly/contained-error circuit breaker, and the
//! shared health surface behind `/healthz` + `/readyz`.
//!
//! Everything here is deterministic given its inputs: the bucket takes
//! an explicit `now`, the breaker runs on the engine's step counter,
//! and shed selection is a pure function of (priority, id) — so the
//! whole layer is unit-testable without a runtime and chaos runs
//! reproduce bit-for-bit.

use crate::engine::request::{Priority, SeqId};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

/// Cost-aware admission gate: a token bucket over *estimated decode
/// cost* (uncached prefill tokens + max_new_tokens), refilled at
/// `rate` tokens/second up to `burst`. `rate <= 0` disables the gate.
#[derive(Debug)]
pub struct TokenBucket {
    rate: f64,
    burst: f64,
    tokens: f64,
    last: Option<Instant>,
}

impl TokenBucket {
    /// A new bucket starts full, so a burst up to `burst` tokens is
    /// admitted immediately after startup.
    pub fn new(rate: f64, burst: f64) -> Self {
        let burst = burst.max(1.0);
        Self { rate, burst, tokens: burst, last: None }
    }

    pub fn enabled(&self) -> bool {
        self.rate > 0.0
    }

    /// Try to take `cost` tokens at time `now`. `Err(retry_after_ms)`
    /// says how long until the deficit refills. A cost above `burst`
    /// is clamped to it, so oversized requests are admitted eventually
    /// instead of starving forever.
    pub fn try_take(&mut self, cost: f64, now: Instant) -> Result<(), u64> {
        if !self.enabled() {
            return Ok(());
        }
        if let Some(last) = self.last {
            let dt = now.saturating_duration_since(last).as_secs_f64();
            self.tokens = (self.tokens + dt * self.rate).min(self.burst);
        }
        self.last = Some(now);
        let cost = cost.max(0.0).min(self.burst);
        if self.tokens >= cost {
            self.tokens -= cost;
            return Ok(());
        }
        let deficit = cost - self.tokens;
        let retry_after_ms = (deficit / self.rate * 1e3).ceil() as u64;
        Err(retry_after_ms.max(1))
    }
}

/// Pick the queued entry to shed so `incoming` can be admitted: only
/// strictly lower classes are eligible, the lowest class goes first,
/// and within a class the youngest entry (highest id — least sunk
/// queue wait) goes first. `None` means nothing outranks the incoming
/// request and the incoming request itself must be rejected.
pub fn shed_victim(
    queued: impl Iterator<Item = (SeqId, Priority)>,
    incoming: Priority,
) -> Option<SeqId> {
    queued
        .filter(|(_, p)| *p < incoming)
        .min_by_key(|(id, p)| (*p, std::cmp::Reverse(*id)))
        .map(|(id, _)| id)
}

/// A breaker transition the engine should surface as a metric.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerTransition {
    Entered,
    Exited,
}

/// Counter-tracked circuit breaker on the engine's step clock: once
/// `threshold` events (Radar anomalies, contained errors, watchdog
/// trips) land within a `window`-step span, the engine flips into
/// exact-attention degraded mode for `cooldown` steps, then recovers.
/// `threshold == 0` disables the breaker.
#[derive(Debug)]
pub struct CircuitBreaker {
    threshold: u32,
    window: u64,
    cooldown: u64,
    /// Step numbers of recent events, oldest first.
    events: VecDeque<u64>,
    degraded_until: Option<u64>,
}

impl CircuitBreaker {
    pub fn new(threshold: u32, window: u64, cooldown: u64) -> Self {
        Self {
            threshold,
            window: window.max(1),
            cooldown: cooldown.max(1),
            events: VecDeque::new(),
            degraded_until: None,
        }
    }

    /// Record one anomaly/error event at engine step `step`.
    pub fn record(&mut self, step: u64) {
        if self.threshold > 0 {
            self.events.push_back(step);
        }
    }

    pub fn degraded(&self) -> bool {
        self.degraded_until.is_some()
    }

    /// Advance the step clock: expire old events, trip on threshold,
    /// recover after cool-down. At most one transition per step.
    pub fn tick(&mut self, step: u64) -> Option<BreakerTransition> {
        if self.threshold == 0 {
            return None;
        }
        if let Some(until) = self.degraded_until {
            if step >= until {
                self.degraded_until = None;
                self.events.clear();
                return Some(BreakerTransition::Exited);
            }
            return None;
        }
        while let Some(&front) = self.events.front() {
            if front + self.window <= step {
                self.events.pop_front();
            } else {
                break;
            }
        }
        if self.events.len() >= self.threshold as usize {
            self.degraded_until = Some(step + self.cooldown);
            self.events.clear();
            return Some(BreakerTransition::Entered);
        }
        None
    }
}

/// Liveness/readiness shared between the engine loop (writer) and HTTP
/// connection threads (readers). Plain atomics: the engine publishes
/// after each step, `/readyz` only ever reads.
#[derive(Debug, Default)]
pub struct HealthState {
    /// Set by SIGTERM or `/admin/drain`; admissions stop immediately.
    draining: AtomicBool,
    /// KV pool at or above the shed watermark.
    overloaded: AtomicBool,
    /// A watchdog trip within the recent quiet window.
    watchdog_unquiet: AtomicBool,
}

impl HealthState {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn begin_drain(&self) {
        self.draining.store(true, Ordering::Release);
    }

    pub fn draining(&self) -> bool {
        self.draining.load(Ordering::Acquire)
    }

    pub fn set_overloaded(&self, v: bool) {
        self.overloaded.store(v, Ordering::Release);
    }

    pub fn set_watchdog_unquiet(&self, v: bool) {
        self.watchdog_unquiet.store(v, Ordering::Release);
    }

    /// Readiness = not draining, KV pool below watermark, watchdog
    /// quiet. Liveness (`/healthz`) is the process answering at all.
    pub fn ready(&self) -> bool {
        !self.draining()
            && !self.overloaded.load(Ordering::Acquire)
            && !self.watchdog_unquiet.load(Ordering::Acquire)
    }
}

/// Replace non-finite logits with a large negative so sampling stays
/// well-defined even if an anomaly slipped past selection-level
/// fallback. Returns true if anything had to be repaired.
pub fn sanitize_logits(logits: &mut [f32]) -> bool {
    let mut repaired = false;
    for x in logits.iter_mut() {
        if !x.is_finite() {
            *x = -1e30;
            repaired = true;
        }
    }
    repaired
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn disabled_bucket_admits_everything() {
        let mut b = TokenBucket::new(0.0, 1.0);
        let t0 = Instant::now();
        for _ in 0..100 {
            assert!(b.try_take(1e9, t0).is_ok());
        }
    }

    #[test]
    fn bucket_enforces_rate_and_computes_retry_after() {
        // 1000 tokens/s, burst 100: the first 100-cost request drains
        // the bucket; the next needs ~50ms to refill 50 tokens.
        let mut b = TokenBucket::new(1000.0, 100.0);
        let t0 = Instant::now();
        assert!(b.try_take(100.0, t0).is_ok());
        let retry = b.try_take(50.0, t0).unwrap_err();
        assert_eq!(retry, 50, "deficit of 50 tokens at 1000/s is 50 ms");
        // After 60ms the bucket holds 60 tokens again.
        let t1 = t0 + Duration::from_millis(60);
        assert!(b.try_take(50.0, t1).is_ok());
    }

    #[test]
    fn bucket_clamps_oversized_costs_to_burst() {
        let mut b = TokenBucket::new(100.0, 10.0);
        let t0 = Instant::now();
        // Cost 1e6 >> burst 10: admitted as a full-bucket take, not
        // rejected forever.
        assert!(b.try_take(1e6, t0).is_ok());
        let retry = b.try_take(1e6, t0).unwrap_err();
        assert_eq!(retry, 100, "full burst at 100/s refills in 100 ms");
        assert!(b.try_take(1e6, t0 + Duration::from_millis(150)).is_ok());
    }

    #[test]
    fn bucket_caps_refill_at_burst() {
        let mut b = TokenBucket::new(1000.0, 50.0);
        let t0 = Instant::now();
        assert!(b.try_take(50.0, t0).is_ok());
        // A long idle period must not bank more than `burst`.
        let t1 = t0 + Duration::from_secs(3600);
        assert!(b.try_take(50.0, t1).is_ok());
        assert!(b.try_take(1.0, t1).is_err());
    }

    #[test]
    fn shed_picks_lowest_priority_then_youngest() {
        let q = [
            (1, Priority::Normal),
            (2, Priority::Batch),
            (3, Priority::Batch),
            (4, Priority::High),
        ];
        // Batch before Normal; youngest batch entry (id 3) first.
        assert_eq!(shed_victim(q.iter().copied(), Priority::High), Some(3));
        // A normal arrival may only displace batch work.
        assert_eq!(shed_victim(q.iter().copied(), Priority::Normal), Some(3));
        // A batch arrival outranks nothing.
        assert_eq!(shed_victim(q.iter().copied(), Priority::Batch), None);
        // Equal priority is never shed (strictly lower only).
        let all_high = [(1, Priority::High), (2, Priority::High)];
        assert_eq!(shed_victim(all_high.iter().copied(), Priority::High), None);
        assert_eq!(shed_victim(std::iter::empty(), Priority::High), None);
    }

    #[test]
    fn breaker_trips_on_threshold_within_window() {
        let mut cb = CircuitBreaker::new(3, 10, 5);
        cb.record(1);
        cb.record(2);
        assert_eq!(cb.tick(2), None, "below threshold");
        assert!(!cb.degraded());
        cb.record(3);
        assert_eq!(cb.tick(3), Some(BreakerTransition::Entered));
        assert!(cb.degraded());
        // Stays degraded through the cool-down, no repeat transitions.
        for s in 4..8 {
            assert_eq!(cb.tick(s), None);
            assert!(cb.degraded());
        }
        assert_eq!(cb.tick(8), Some(BreakerTransition::Exited));
        assert!(!cb.degraded());
        assert_eq!(cb.tick(9), None);
    }

    #[test]
    fn breaker_window_expires_stale_events() {
        let mut cb = CircuitBreaker::new(2, 5, 4);
        cb.record(1);
        assert_eq!(cb.tick(1), None);
        // Step 10: the step-1 event left the 5-step window long ago.
        cb.record(10);
        assert_eq!(cb.tick(10), None, "stale events must not count");
        cb.record(11);
        assert_eq!(cb.tick(11), Some(BreakerTransition::Entered));
    }

    #[test]
    fn breaker_disabled_at_zero_threshold() {
        let mut cb = CircuitBreaker::new(0, 5, 5);
        for s in 1..50 {
            cb.record(s);
            assert_eq!(cb.tick(s), None);
            assert!(!cb.degraded());
        }
    }

    #[test]
    fn health_readiness_composes_all_conditions() {
        let h = HealthState::new();
        assert!(h.ready(), "fresh engine is ready");
        h.set_overloaded(true);
        assert!(!h.ready());
        h.set_overloaded(false);
        h.set_watchdog_unquiet(true);
        assert!(!h.ready());
        h.set_watchdog_unquiet(false);
        assert!(h.ready());
        h.begin_drain();
        assert!(h.draining());
        assert!(!h.ready(), "draining is terminal for readiness");
    }

    #[test]
    fn sanitize_replaces_only_nonfinite_logits() {
        let mut v = vec![0.5, f32::NAN, f32::INFINITY, -2.0, f32::NEG_INFINITY];
        assert!(sanitize_logits(&mut v));
        assert_eq!(v[0], 0.5);
        assert_eq!(v[3], -2.0);
        assert!(v.iter().all(|x| x.is_finite()));
        assert_eq!(v[1], -1e30);
        let mut clean = vec![1.0f32, 2.0];
        assert!(!sanitize_logits(&mut clean));
        assert_eq!(clean, vec![1.0, 2.0]);
    }
}
