//! L3 engine: prefill/decode scheduling, continuous batching, and the
//! two decode pipelines (fused single-dispatch for query-independent
//! policies; per-layer qkv -> select -> gather -> attn_mlp for Radar).

mod batcher;
mod core;
mod overload;
mod request;
pub mod staging;

pub use batcher::{group_by_bucket, preemption_victim, BatchGroup};
pub use core::{Engine, RecoveryReport, StepStats};
pub use overload::{
    sanitize_logits, shed_victim, BreakerTransition, CircuitBreaker, HealthState, TokenBucket,
};
pub use request::{
    resolved_sampling, FinishReason, GenRequest, GenResult, Priority, SeqId, Sequence,
    SessionEvent, SessionHandle, SessionResult, SubmitError, Usage,
};
