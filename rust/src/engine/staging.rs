//! Incremental K/V staging for the decode hot path.
//!
//! Re-gathering every (layer, head) plane's full selection from the
//! paged cache each step is O(S·dh) of host copies per plane, yet
//! consecutive decode steps differ only by the sliding-window tip and
//! occasional top-k churn. `StagedPlanes` is a per-sequence arena that
//! retains last step's gathered K/V rows per plane; each step the new
//! selection is diffed against the staged one and only changed rows are
//! gathered from the cache — the common case (window grows by one
//! token, top-k unchanged) becomes an O(dh) append.
//!
//! Soundness: a token index in a `SeqCache` is append-only — its K/V
//! values never change once written (copy-on-write block copies
//! preserve contents). Staged rows therefore stay valid for the
//! lifetime of the cache; the arena must only be invalidated when the
//! cache itself is torn down (preemption frees the blocks and the
//! sequence re-prefills from scratch). Everything else — restructure
//! boundaries, anomaly fallbacks, fused-batch bucket changes,
//! degraded-mode full-context selections — is just a bigger diff and
//! needs no special-casing: the diff naturally degrades to a full
//! gather, never to a wrong answer.

use crate::kvcache::{BlockPool, SeqCache};
use crate::util::threadpool::ThreadPool;

/// Per-step staging telemetry; accumulated across planes, then flushed
/// into `Metrics` by the engine.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct StageStats {
    /// Bytes a full re-gather of every staged selection would copy
    /// (K + V) — the baseline the delta path is measured against.
    pub bytes_full: u64,
    /// Bytes actually gathered from the paged cache (K + V).
    pub bytes_delta: u64,
    /// Planes where the delta path gathered fewer rows than a full
    /// restage would have.
    pub delta_hits: u64,
    /// Planes that took the full-gather path (cold start, delta
    /// disabled, or invalidated arena).
    pub full_restages: u64,
}

impl StageStats {
    pub fn merge(&mut self, o: &StageStats) {
        self.bytes_full += o.bytes_full;
        self.bytes_delta += o.bytes_delta;
        self.delta_hits += o.delta_hits;
        self.full_restages += o.full_restages;
    }
}

/// One plane's staged rows: the selection it was gathered for plus the
/// gathered K/V rows, tightly packed (row `i` at `i * dh`). Tight
/// packing makes the arena independent of the padded dispatch-buffer
/// bucket, so batch-slot and S-bucket changes never force a restage.
#[derive(Default)]
pub struct StagedPlane {
    sel: Vec<u32>,
    k: Vec<f32>,
    v: Vec<f32>,
}

impl StagedPlane {
    /// Number of staged rows (test/introspection hook).
    pub fn len(&self) -> usize {
        self.sel.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sel.is_empty()
    }

    fn clear(&mut self) {
        self.sel.clear();
        self.k.clear();
        self.v.clear();
    }

    /// Stage plane (l, h)'s selection into `dst_k`/`dst_v` (each
    /// `[S, dh]`, `S >= sel.len()`; rows past `sel.len()` untouched —
    /// callers mask them), reusing staged rows where the selection
    /// overlaps last step's.
    ///
    /// The diff is prefix + one relocation run: rows up to the longest
    /// common prefix are reused in place; if the first divergent token
    /// still exists further right in the staged selection (window
    /// front slid, a segment was dropped), its run is memmoved left;
    /// the remainder is gathered from the cache. Selections are sorted
    /// and deduped (policy invariant), which is what makes the prefix
    /// diff effective. With `delta == false` the arena is bypassed for
    /// reuse (but still refreshed) and every row is gathered — the
    /// force-full baseline used by the bench and byte-identity tests.
    #[allow(clippy::too_many_arguments)]
    pub fn stage(
        &mut self,
        cache: &SeqCache,
        pool: &BlockPool,
        l: usize,
        h: usize,
        sel: &[u32],
        dst_k: &mut [f32],
        dst_v: &mut [f32],
        delta: bool,
        stats: &mut StageStats,
    ) {
        let dh = pool.config().d_head;
        let n_new = sel.len();
        let row_bytes = (2 * dh * std::mem::size_of::<f32>()) as u64;
        stats.bytes_full += n_new as u64 * row_bytes;
        if n_new == 0 {
            // Empty-selection plane: nothing staged, dst untouched
            // (the caller masks the whole row NEG).
            self.clear();
            return;
        }
        let gathered = if !delta || self.sel.is_empty() {
            self.k.resize(n_new * dh, 0.0);
            self.v.resize(n_new * dh, 0.0);
            cache.gather_plane(pool, l, h, sel, &mut self.k, &mut self.v);
            stats.full_restages += 1;
            n_new
        } else {
            let max_lcp = self.sel.len().min(n_new);
            let mut lcp = 0;
            while lcp < max_lcp && self.sel[lcp] == sel[lcp] {
                lcp += 1;
            }
            let mut kept = lcp;
            if lcp < n_new {
                if let Some(off) = self.sel[lcp..].iter().position(|&x| x == sel[lcp]) {
                    // `off > 0` always: lcp is maximal, so the staged
                    // row at `lcp` itself cannot match.
                    let src = lcp + off;
                    let mut run = 1;
                    while lcp + run < n_new
                        && src + run < self.sel.len()
                        && self.sel[src + run] == sel[lcp + run]
                    {
                        run += 1;
                    }
                    // memmove (left shift): dst start < src start, both
                    // ranges inside the pre-resize arena.
                    self.k.copy_within(src * dh..(src + run) * dh, lcp * dh);
                    self.v.copy_within(src * dh..(src + run) * dh, lcp * dh);
                    kept = lcp + run;
                }
            }
            self.k.resize(n_new * dh, 0.0);
            self.v.resize(n_new * dh, 0.0);
            if kept < n_new {
                cache.gather_plane(
                    pool,
                    l,
                    h,
                    &sel[kept..],
                    &mut self.k[kept * dh..],
                    &mut self.v[kept * dh..],
                );
            }
            n_new - kept
        };
        stats.bytes_delta += gathered as u64 * row_bytes;
        if delta && gathered < n_new {
            stats.delta_hits += 1;
        }
        self.sel.clear();
        self.sel.extend_from_slice(sel);
        let n = n_new * dh;
        dst_k[..n].copy_from_slice(&self.k[..n]);
        dst_v[..n].copy_from_slice(&self.v[..n]);
    }
}

/// Per-sequence arena: one `StagedPlane` per (layer, head).
pub struct StagedPlanes {
    pub planes: Vec<StagedPlane>,
}

impl StagedPlanes {
    pub fn new(lh: usize) -> Self {
        let mut planes = Vec::with_capacity(lh);
        planes.resize_with(lh, StagedPlane::default);
        Self { planes }
    }

    /// Drop all staged rows. Must be called whenever the sequence's
    /// cache is torn down (preemption) so the next step restages from
    /// the rebuilt cache.
    pub fn invalidate(&mut self) {
        for p in &mut self.planes {
            p.clear();
        }
    }

    /// Stage plane index `p` (= `l * n_heads + h`). See
    /// [`StagedPlane::stage`].
    #[allow(clippy::too_many_arguments)]
    pub fn stage_plane(
        &mut self,
        p: usize,
        cache: &SeqCache,
        pool: &BlockPool,
        l: usize,
        h: usize,
        sel: &[u32],
        dst_k: &mut [f32],
        dst_v: &mut [f32],
        delta: bool,
        stats: &mut StageStats,
    ) {
        self.planes[p].stage(cache, pool, l, h, sel, dst_k, dst_v, delta, stats);
    }
}

/// Stage a contiguous run of planes into a dispatch buffer laid out
/// `[planes.len(), s, dh]` (K/V) and `[planes.len(), s]` (mask).
/// Plane-local index `i` maps to global plane `first_plane + i`
/// (`= l * n_heads + h`). Valid mask slots become 0.0, the rest `neg`;
/// an empty selection masks its whole row without touching K/V.
#[allow(clippy::too_many_arguments)]
pub fn stage_planes_serial(
    planes: &mut [StagedPlane],
    first_plane: usize,
    n_heads: usize,
    cache: &SeqCache,
    pool: &BlockPool,
    per_plane: &[Vec<u32>],
    s: usize,
    dst_k: &mut [f32],
    dst_v: &mut [f32],
    dst_mask: &mut [f32],
    delta: bool,
    neg: f32,
) -> StageStats {
    let dh = pool.config().d_head;
    let mut stats = StageStats::default();
    for (i, plane) in planes.iter_mut().enumerate() {
        let p = first_plane + i;
        let sel = &per_plane[i];
        plane.stage(
            cache,
            pool,
            p / n_heads,
            p % n_heads,
            sel,
            &mut dst_k[i * s * dh..(i + 1) * s * dh],
            &mut dst_v[i * s * dh..(i + 1) * s * dh],
            delta,
            &mut stats,
        );
        let m = &mut dst_mask[i * s..(i + 1) * s];
        m[..sel.len()].fill(0.0);
        m[sel.len()..].fill(neg);
    }
    stats
}

/// Sharded variant of [`stage_planes_serial`]: planes are chunked into
/// up to `n_jobs` runs, each staged by a pool worker into disjoint
/// buffer slices. Per-plane staging is independent, so the result is
/// byte-identical to the serial path in every buffer and stat.
#[allow(clippy::too_many_arguments)]
pub fn stage_planes_sharded(
    tp: &ThreadPool,
    n_jobs: usize,
    planes: &mut [StagedPlane],
    first_plane: usize,
    n_heads: usize,
    cache: &SeqCache,
    pool: &BlockPool,
    per_plane: &[Vec<u32>],
    s: usize,
    dst_k: &mut [f32],
    dst_v: &mut [f32],
    dst_mask: &mut [f32],
    delta: bool,
    neg: f32,
) -> StageStats {
    let dh = pool.config().d_head;
    let lh = planes.len();
    let chunk = lh.div_ceil(n_jobs.max(1));
    if chunk == 0 || lh <= chunk {
        return stage_planes_serial(
            planes, first_plane, n_heads, cache, pool, per_plane, s, dst_k, dst_v, dst_mask,
            delta, neg,
        );
    }
    let n_chunks = lh.div_ceil(chunk);
    let mut job_stats = vec![StageStats::default(); n_chunks];
    let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = planes
        .chunks_mut(chunk)
        .zip(per_plane.chunks(chunk))
        .zip(dst_k.chunks_mut(chunk * s * dh))
        .zip(dst_v.chunks_mut(chunk * s * dh))
        .zip(dst_mask.chunks_mut(chunk * s))
        .zip(job_stats.iter_mut())
        .enumerate()
        .map(|(j, (((((pl, sels), kc), vc), mc), st))| {
            Box::new(move || {
                *st = stage_planes_serial(
                    pl,
                    first_plane + j * chunk,
                    n_heads,
                    cache,
                    pool,
                    sels,
                    s,
                    kc,
                    vc,
                    mc,
                    delta,
                    neg,
                );
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    tp.scoped(jobs);
    let mut stats = StageStats::default();
    for st in &job_stats {
        stats.merge(st);
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::kvcache::BLOCK_TOKENS;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            d_head: 4,
            d_ffn: 16,
            n_feat: 8,
            max_train_len: 64,
            vocab: 16,
        }
    }

    fn grown_cache(n: usize) -> (BlockPool, SeqCache) {
        let c = cfg();
        let mut pool = BlockPool::new(&c, 8, 256);
        let mut seq = SeqCache::new(8);
        for t in 0..n {
            let k: Vec<f32> = (0..4 * 4).map(|i| (t * 100 + i) as f32).collect();
            let v: Vec<f32> = k.iter().map(|x| x + 0.25).collect();
            let f = vec![0.0; 4 * 8];
            seq.append(&mut pool, &k, &v, &f).unwrap();
        }
        (pool, seq)
    }

    fn full_gather(pool: &BlockPool, seq: &SeqCache, sel: &[u32]) -> (Vec<f32>, Vec<f32>) {
        let dh = pool.config().d_head;
        let mut k = vec![0.0; sel.len() * dh];
        let mut v = vec![0.0; sel.len() * dh];
        seq.gather_plane(pool, 1, 1, sel, &mut k, &mut v);
        (k, v)
    }

    fn stage_once(
        plane: &mut StagedPlane,
        pool: &BlockPool,
        seq: &SeqCache,
        sel: &[u32],
        delta: bool,
    ) -> (Vec<f32>, Vec<f32>, StageStats) {
        let dh = pool.config().d_head;
        let mut k = vec![-1.0; (sel.len() + 3) * dh];
        let mut v = vec![-1.0; (sel.len() + 3) * dh];
        let mut st = StageStats::default();
        plane.stage(seq, pool, 1, 1, sel, &mut k, &mut v, delta, &mut st);
        k.truncate(sel.len() * dh);
        v.truncate(sel.len() * dh);
        (k, v, st)
    }

    #[test]
    fn cold_start_equals_full_gather() {
        let (pool, seq) = grown_cache(40);
        let sel: Vec<u32> = vec![0, 1, 2, 17, 18, 35, 36, 37, 38, 39];
        let mut plane = StagedPlane::default();
        let (k, v, st) = stage_once(&mut plane, &pool, &seq, &sel, true);
        let (wk, wv) = full_gather(&pool, &seq, &sel);
        assert_eq!(k, wk);
        assert_eq!(v, wv);
        assert_eq!(st.full_restages, 1);
        assert_eq!(st.delta_hits, 0, "cold start is not a delta hit");
        assert_eq!(st.bytes_delta, st.bytes_full);
    }

    #[test]
    fn append_step_gathers_one_row() {
        let (pool, seq) = grown_cache(40);
        let mut sel: Vec<u32> = (30..39).collect();
        let mut plane = StagedPlane::default();
        stage_once(&mut plane, &pool, &seq, &sel, true);
        sel.push(39); // window grows by one token
        let (k, v, st) = stage_once(&mut plane, &pool, &seq, &sel, true);
        let (wk, wv) = full_gather(&pool, &seq, &sel);
        assert_eq!(k, wk);
        assert_eq!(v, wv);
        assert_eq!(st.delta_hits, 1);
        let row = (2 * 4 * 4) as u64; // K+V * dh * sizeof(f32)
        assert_eq!(st.bytes_delta, row, "append stages exactly one row");
        assert_eq!(st.bytes_full, 10 * row);
    }

    #[test]
    fn window_slide_memmoves_instead_of_regathering() {
        let (pool, seq) = grown_cache(40);
        let mut plane = StagedPlane::default();
        let sel0: Vec<u32> = (20..30).collect();
        stage_once(&mut plane, &pool, &seq, &sel0, true);
        // Front slides by one, tip advances by one: 21..=30.
        let sel1: Vec<u32> = (21..31).collect();
        let (k, v, st) = stage_once(&mut plane, &pool, &seq, &sel1, true);
        let (wk, wv) = full_gather(&pool, &seq, &sel1);
        assert_eq!(k, wk);
        assert_eq!(v, wv);
        assert_eq!(st.delta_hits, 1);
        let row = (2 * 4 * 4) as u64;
        assert_eq!(st.bytes_delta, row, "slide relocates 9 rows, gathers 1");
    }

    #[test]
    fn topk_churn_stays_byte_identical() {
        let (pool, seq) = grown_cache(64);
        let mut delta_plane = StagedPlane::default();
        let mut full_plane = StagedPlane::default();
        // Segment swap mid-selection + growing window, across steps.
        let steps: Vec<Vec<u32>> = vec![
            [0, 1, 8, 9, 10, 11, 40, 41, 42].into(),
            [0, 1, 8, 9, 10, 11, 40, 41, 42, 43].into(),
            [0, 1, 16, 17, 18, 19, 40, 41, 42, 43, 44].into(),
            [0, 1, 16, 17, 18, 19, 41, 42, 43, 44, 45].into(),
            [0, 1, 8, 9, 10, 11, 16, 17, 41, 42, 43, 44, 45, 46].into(),
        ];
        for sel in &steps {
            let (dk, dv, _) = stage_once(&mut delta_plane, &pool, &seq, sel, true);
            let (fk, fv, _) = stage_once(&mut full_plane, &pool, &seq, sel, false);
            assert_eq!(dk, fk, "K diverged at sel {sel:?}");
            assert_eq!(dv, fv, "V diverged at sel {sel:?}");
        }
    }

    #[test]
    fn force_full_never_counts_hits() {
        let (pool, seq) = grown_cache(40);
        let mut plane = StagedPlane::default();
        let sel: Vec<u32> = (0..20).collect();
        let (_, _, st0) = stage_once(&mut plane, &pool, &seq, &sel, false);
        let (_, _, st1) = stage_once(&mut plane, &pool, &seq, &sel, false);
        for st in [st0, st1] {
            assert_eq!(st.delta_hits, 0);
            assert_eq!(st.bytes_delta, st.bytes_full);
            assert_eq!(st.full_restages, 1);
        }
    }

    #[test]
    fn identical_selection_gathers_nothing() {
        let (pool, seq) = grown_cache(40);
        let mut plane = StagedPlane::default();
        let sel: Vec<u32> = (10..30).collect();
        stage_once(&mut plane, &pool, &seq, &sel, true);
        let (k, v, st) = stage_once(&mut plane, &pool, &seq, &sel, true);
        let (wk, wv) = full_gather(&pool, &seq, &sel);
        assert_eq!(k, wk);
        assert_eq!(v, wv);
        assert_eq!(st.bytes_delta, 0);
        assert_eq!(st.delta_hits, 1);
    }

    #[test]
    fn empty_selection_clears_and_leaves_dst_untouched() {
        let (pool, seq) = grown_cache(20);
        let mut plane = StagedPlane::default();
        stage_once(&mut plane, &pool, &seq, &[5, 6, 7], true);
        assert_eq!(plane.len(), 3);
        let mut k = vec![3.0; 8];
        let mut v = vec![4.0; 8];
        let mut st = StageStats::default();
        plane.stage(&seq, &pool, 1, 1, &[], &mut k, &mut v, true, &mut st);
        assert!(plane.is_empty());
        assert!(k.iter().all(|&x| x == 3.0));
        assert!(v.iter().all(|&x| x == 4.0));
        assert_eq!(st.bytes_full, 0);
    }

    #[test]
    fn invalidate_forces_full_restage() {
        let (pool, seq) = grown_cache(40);
        let mut planes = StagedPlanes::new(4);
        let sel: Vec<u32> = (0..16).collect();
        let dh = 4;
        let mut k = vec![0.0; sel.len() * dh];
        let mut v = vec![0.0; sel.len() * dh];
        let mut st = StageStats::default();
        planes.stage_plane(3, &seq, &pool, 1, 1, &sel, &mut k, &mut v, true, &mut st);
        planes.invalidate();
        let mut st = StageStats::default();
        planes.stage_plane(3, &seq, &pool, 1, 1, &sel, &mut k, &mut v, true, &mut st);
        assert_eq!(st.full_restages, 1, "invalidated arena must restage");
        assert_eq!(st.delta_hits, 0);
    }

    #[test]
    fn prop_random_selection_walks_match_full_gather() {
        // Deterministic pseudo-random walk over selections (sorted,
        // deduped, drawn from a growing prefix) — delta staging must
        // remain byte-identical to a fresh full gather at every step.
        use crate::util::prng::SplitMix64;
        let (pool, seq) = grown_cache(3 * BLOCK_TOKENS + 7);
        let t_max = (3 * BLOCK_TOKENS + 7) as u64;
        let mut rng = SplitMix64::new(0xC0FFEE);
        let mut plane = StagedPlane::default();
        for step in 0..50 {
            let t = 8 + (step as u64 * 7) % (t_max - 8);
            let n = 1 + rng.below(t.min(24)) as usize;
            let mut sel: Vec<u32> = (0..n).map(|_| rng.below(t) as u32).collect();
            sel.sort_unstable();
            sel.dedup();
            let (k, v, _) = stage_once(&mut plane, &pool, &seq, &sel, true);
            let (wk, wv) = full_gather(&pool, &seq, &sel);
            assert_eq!(k, wk, "step {step} sel {sel:?}");
            assert_eq!(v, wv, "step {step} sel {sel:?}");
        }
    }

    #[test]
    fn sharded_staging_matches_serial() {
        let (pool, seq) = grown_cache(48);
        // 4 planes (l=2, h=2) with distinct selections, one empty.
        let sels: Vec<Vec<u32>> = vec![
            (0..10).collect(),
            vec![0, 1, 20, 21, 22, 40, 41],
            (30..47).collect(),
            vec![],
        ];
        let (s, dh) = (20, 4);
        let run = |tp: Option<&ThreadPool>| {
            let mut planes = StagedPlanes::new(4);
            let mut k = vec![-1.0; 4 * s * dh];
            let mut v = vec![-1.0; 4 * s * dh];
            let mut m = vec![-1.0; 4 * s];
            let st = match tp {
                Some(tp) => stage_planes_sharded(
                    tp, 3, &mut planes.planes, 0, 2, &seq, &pool, &sels, s, &mut k, &mut v,
                    &mut m, true, -1e30,
                ),
                None => stage_planes_serial(
                    &mut planes.planes, 0, 2, &seq, &pool, &sels, s, &mut k, &mut v, &mut m,
                    true, -1e30,
                ),
            };
            (k, v, m, st)
        };
        let tp = ThreadPool::new(3, "stage-test");
        let (k_s, v_s, m_s, st_s) = run(None);
        let (k_p, v_p, m_p, st_p) = run(Some(&tp));
        assert_eq!(k_s, k_p);
        assert_eq!(v_s, v_p);
        assert_eq!(m_s, m_p, "mask must be identical, incl. empty plane all-NEG");
        assert_eq!(st_s, st_p);
        // Empty plane's mask row is fully NEG.
        assert!(m_s[3 * s..].iter().all(|&x| x == -1e30));
    }

    #[test]
    fn stats_merge_adds_fields() {
        let mut a = StageStats { bytes_full: 1, bytes_delta: 2, delta_hits: 3, full_restages: 4 };
        let b = StageStats { bytes_full: 10, bytes_delta: 20, delta_hits: 30, full_restages: 40 };
        a.merge(&b);
        assert_eq!(
            a,
            StageStats { bytes_full: 11, bytes_delta: 22, delta_hits: 33, full_restages: 44 }
        );
    }
}
