//! Continuous batcher: groups runnable sequences into decode batches
//! compatible with one compiled artifact (same S bucket; batch rows
//! padded up to a compiled B bucket).

use super::request::SeqId;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BatchGroup {
    /// Sequences in this dispatch (<= the resolved B bucket).
    pub seq_ids: Vec<SeqId>,
    /// The S bucket all rows share (max over members' needs, rounded).
    pub bucket_s: usize,
}

/// Round a needed length up to the smallest available bucket.
pub fn round_bucket(need: usize, buckets: &[usize]) -> Option<usize> {
    buckets.iter().copied().filter(|&b| b >= need).min()
}

/// Group (seq, needed_s) pairs into batch groups.
///
/// Strategy (throughput-greedy, like vLLM's batch packer): sort by
/// needed S; pack consecutive runs that share a rounded bucket, cutting
/// at `max_batch`. Padding waste is bounded by bucket granularity.
pub fn group_by_bucket(
    needs: &[(SeqId, usize)],
    s_buckets: &[usize],
    max_batch: usize,
) -> Vec<BatchGroup> {
    let mut sorted: Vec<(SeqId, usize)> = needs.to_vec();
    sorted.sort_by_key(|&(_, s)| s);
    let mut out: Vec<BatchGroup> = Vec::new();
    for (id, need) in sorted {
        let bucket = match round_bucket(need, s_buckets) {
            Some(b) => b,
            None => {
                // No compiled bucket fits: isolate; the engine will
                // surface the resolve error for this sequence.
                out.push(BatchGroup { seq_ids: vec![id], bucket_s: need });
                continue;
            }
        };
        if let Some(last) = out.last_mut() {
            if last.bucket_s == bucket && last.seq_ids.len() < max_batch {
                last.seq_ids.push(id);
                continue;
            }
        }
        out.push(BatchGroup { seq_ids: vec![id], bucket_s: bucket });
    }
    out
}

/// Admission order for queued sequences: shortest uncached prefill
/// first (prefix-cache hits jump the queue — their remaining work is
/// tiny, so serving them first lowers mean TTFT without starving cold
/// prompts, whose wait is bounded by the queue cap). Ties break FIFO by
/// sequence id, which increases monotonically with submit order.
pub fn admission_order(costs: &[(SeqId, usize)]) -> Vec<SeqId> {
    let mut sorted = costs.to_vec();
    sorted.sort_by_key(|&(id, cost)| (cost, id));
    sorted.into_iter().map(|(id, _)| id).collect()
}

/// Which active sequence to preempt under KV pressure, given
/// `(seq, generated_tokens)` pairs: the one with the least decode
/// progress (its lost work is the cheapest to replay through the
/// prefix-cache-warm re-prefill), ties broken toward the youngest
/// (highest id — oldest requests are closest to their deadline).
pub fn preemption_victim(candidates: impl Iterator<Item = (SeqId, usize)>) -> Option<SeqId> {
    candidates
        .min_by_key(|&(id, progress)| (progress, std::cmp::Reverse(id)))
        .map(|(id, _)| id)
}

#[cfg(test)]
mod tests {
    use super::*;

    const BUCKETS: &[usize] = &[128, 256, 512, 1024];

    #[test]
    fn round_up() {
        assert_eq!(round_bucket(1, BUCKETS), Some(128));
        assert_eq!(round_bucket(128, BUCKETS), Some(128));
        assert_eq!(round_bucket(129, BUCKETS), Some(256));
        assert_eq!(round_bucket(2000, BUCKETS), None);
    }

    #[test]
    fn groups_compatible_sequences() {
        let needs = vec![(1, 100), (2, 120), (3, 500), (4, 90), (5, 110)];
        let groups = group_by_bucket(&needs, BUCKETS, 4);
        // 4 sequences fit the 128 bucket (batch cap 4), one in 512.
        let g128: Vec<_> = groups.iter().filter(|g| g.bucket_s == 128).collect();
        assert_eq!(g128.len(), 1);
        assert_eq!(g128[0].seq_ids.len(), 4);
        assert!(groups.iter().any(|g| g.bucket_s == 512 && g.seq_ids.len() == 1));
    }

    #[test]
    fn batch_cap_respected() {
        let needs: Vec<(SeqId, usize)> = (0..10).map(|i| (i, 50)).collect();
        let groups = group_by_bucket(&needs, BUCKETS, 4);
        assert_eq!(groups.len(), 3); // 4+4+2
        assert!(groups.iter().all(|g| g.seq_ids.len() <= 4));
        let total: usize = groups.iter().map(|g| g.seq_ids.len()).sum();
        assert_eq!(total, 10);
    }

    #[test]
    fn no_starvation_all_sequences_placed() {
        let needs: Vec<(SeqId, usize)> =
            (0..25).map(|i| (i, (i as usize * 37) % 900 + 1)).collect();
        let groups = group_by_bucket(&needs, BUCKETS, 4);
        let mut seen: Vec<SeqId> = groups.iter().flat_map(|g| g.seq_ids.clone()).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..25).collect::<Vec<_>>());
    }

    #[test]
    fn admission_prefers_cheap_prefills_fifo_on_ties() {
        // Seq 3 hit the prefix cache (16 uncached tokens) and jumps
        // ahead of the earlier-but-colder 1 and 2; equal costs keep
        // submit order.
        let costs = vec![(1, 512), (2, 512), (3, 16), (4, 128)];
        assert_eq!(admission_order(&costs), vec![3, 4, 1, 2]);
        assert!(admission_order(&[]).is_empty());
    }

    #[test]
    fn victim_is_lowest_progress_then_youngest() {
        // Least progress loses, regardless of id order.
        let v = preemption_victim(vec![(1, 5), (2, 2), (3, 9)].into_iter());
        assert_eq!(v, Some(2));
        // Ties go to the youngest (highest id).
        let v = preemption_victim(vec![(1, 3), (2, 3), (3, 7)].into_iter());
        assert_eq!(v, Some(2));
        assert_eq!(preemption_victim(std::iter::empty()), None);
    }

    #[test]
    fn prop_grouping_preserves_membership_and_caps() {
        use crate::util::minitest::check;
        use crate::util::prng::SplitMix64;
        check(
            11,
            60,
            |r: &mut SplitMix64| {
                let n = r.below(20) as usize;
                (0..n).map(|i| (i as u64, 1 + r.below(1200) as usize)).collect::<Vec<(u64, usize)>>()
            },
            |needs| {
                let groups = group_by_bucket(needs, BUCKETS, 4);
                let mut seen: Vec<u64> =
                    groups.iter().flat_map(|g| g.seq_ids.clone()).collect();
                seen.sort_unstable();
                let mut want: Vec<u64> = needs.iter().map(|&(i, _)| i).collect();
                want.sort_unstable();
                if seen != want {
                    return Err("membership not preserved".into());
                }
                for g in &groups {
                    if g.seq_ids.len() > 4 {
                        return Err("batch cap violated".into());
                    }
                    for id in &g.seq_ids {
                        let need = needs.iter().find(|&&(i, _)| i == *id).unwrap().1;
                        if need <= 1024 && g.bucket_s < need {
                            return Err(format!("seq {id} need {need} > bucket {}", g.bucket_s));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
