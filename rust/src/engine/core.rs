//! The engine: owns the block pool, sequences, and the step loop.
//!
//! Prefill: 128-token chunks with full attention over the growing past
//! (padded to P buckets). Decode: two pipelines —
//!
//! - **fused** (vanilla/streaming/h2o/snapkv/subgen): selection is
//!   query-independent, so one `decode_b{B}_s{S}` dispatch per step
//!   covers all layers; sequences are continuously batched.
//! - **per-layer** (radar + ablations): Algorithm 1 needs phi(q) at
//!   layer l before the layer-l gather, so each layer runs
//!   `qkv -> select -> gather -> attn_mlp`; embedding lookup and the
//!   final head are host-side (verified against goldens).
//!
//! Fault isolation: every per-sequence step body (prefill, fused row
//! staging/finish, radar advance) runs under `catch_unwind`, so a panic
//! or error in one sequence finishes only that session with an `Error`
//! event and frees its blocks. KV exhaustion is a scheduling event, not
//! a failure: the lowest-progress sequence is preempted and requeued
//! through admission (re-prefilling warm via the prefix cache), bounded
//! by `max_preemptions`. Deadlines (`timeout_ms`, `queue_timeout_ms`)
//! are enforced by a per-step sweep. `fail_all` remains only for true
//! process shutdown.

use super::batcher::{admission_order, group_by_bucket, preemption_victim};
use super::overload::{
    sanitize_logits, shed_victim, BreakerTransition, CircuitBreaker, HealthState, TokenBucket,
};
use super::request::{
    resolved_sampling, FinishReason, GenRequest, GenResult, PolicyHolder, Priority, SeqId,
    Sequence, SessionEvent, SessionHandle, SubmitError, Usage,
};
use super::staging::{stage_planes_serial, stage_planes_sharded, StageStats};
use crate::config::ServingConfig;
use crate::faults::ActiveFaults;
use crate::kvcache::{BlockPool, CacheExhausted, SeqCache, BLOCK_TOKENS};
use crate::metrics::Metrics;
use crate::model::{embed, head, log_prob};
use crate::policy::{SelectCtx, Selection};
use crate::prefix::PrefixIndex;
use crate::recovery::{AdmitRecord, Journal, SessionMirror, Terminal};
use crate::runtime::Runtime;
use crate::util::threadpool::{Channel, ThreadPool};
use anyhow::{anyhow, Result};
use std::collections::{BTreeMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::time::{Duration, Instant};

const NEG: f32 = -1e30;

/// Retry hint on a watermark rejection with no sheddable victim: KV
/// pressure clears on the decode timescale, not the admission one.
const SHED_RETRY_MS: u64 = 1000;

/// What a queue entry carries: a fresh request, or a preempted
/// sequence waiting to re-prefill its prompt + generated tokens.
enum PendingWork {
    Fresh(GenRequest),
    Resume(Box<Sequence>),
}

/// A submitted-but-not-yet-admitted session (the bounded queue entry).
struct PendingSession {
    id: SeqId,
    work: PendingWork,
    /// `None` only for preempted legacy (`add`) sequences.
    events: Option<Channel<SessionEvent>>,
    cancel: Arc<AtomicBool>,
    /// Original submit time (TTFT anchor; survives preemption).
    queued_at: Instant,
    /// When this entry joined the queue (queue-wait deadline anchor).
    enqueued_at: Instant,
    deadline: Option<Instant>,
}

impl PendingSession {
    /// Tokens this entry would prefill if admitted now (the prompt,
    /// plus already-generated tokens for preempted sequences).
    fn prefill_tokens(&self) -> &[i32] {
        match &self.work {
            PendingWork::Fresh(req) => &req.prompt,
            PendingWork::Resume(seq) => &seq.tokens,
        }
    }

    fn wants_prefix_cache(&self) -> bool {
        match &self.work {
            PendingWork::Fresh(req) => req.prefix_cache,
            PendingWork::Resume(seq) => seq.prefix_cache,
        }
    }

    /// Usage reported on a terminal event delivered from the queue
    /// (preempted sequences keep their partial-progress accounting).
    fn terminal_usage(&self) -> Usage {
        match &self.work {
            PendingWork::Fresh(_) => Usage::default(),
            PendingWork::Resume(seq) => seq.usage(),
        }
    }

    /// Shed eligibility class. Preempted sequences were already
    /// admitted once (tokens may have streamed to the client), so they
    /// are never displaced by a fresh arrival.
    fn priority(&self) -> Priority {
        match &self.work {
            PendingWork::Fresh(req) => req.priority,
            PendingWork::Resume(_) => Priority::High,
        }
    }
}

/// One sequence's slice of a fused batch output.
struct FusedRowOut<'a> {
    logits: &'a [f32],
    k_new: &'a [f32],
    v_new: &'a [f32],
    feat_new: &'a [f32],
    probs: &'a [f32],
    s: usize,
}

/// Resolve a request deadline: the request's own `timeout_ms` wins
/// (`Some(0)` opts out entirely), else the engine default if nonzero.
fn effective_deadline(req_ms: Option<u64>, default_ms: u64, from: Instant) -> Option<Instant> {
    let ms = match req_ms {
        Some(0) => return None,
        Some(ms) => ms,
        None if default_ms > 0 => default_ms,
        None => return None,
    };
    Some(from + Duration::from_millis(ms))
}

/// Best-effort panic payload formatting (payloads are `&str` or
/// `String` in practice).
fn panic_msg(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

pub struct Engine {
    pub rt: Arc<Runtime>,
    pub cfg: ServingConfig,
    pub pool: BlockPool,
    pub metrics: Arc<Metrics>,
    /// Shared-prefix radix index (KV block runs + frozen Radar
    /// summaries keyed by prompt prefix).
    pub prefix: PrefixIndex,
    seqs: BTreeMap<SeqId, Sequence>,
    /// Bounded admission queue; `submit` rejects once it is full so the
    /// HTTP layer can answer 429 instead of buffering unboundedly.
    /// (Preemption requeues bypass the cap: they were already admitted.)
    pending: VecDeque<PendingSession>,
    next_id: SeqId,
    /// Scripted fault injection (empty outside chaos tests).
    faults: ActiveFaults,
    /// 1-based step counter; the fault plan's clock.
    step_no: u64,
    /// Cost-aware admission gate (disabled unless `admit_rate > 0`).
    bucket: TokenBucket,
    /// Anomaly/contained-error breaker; tripping flips Radar sequences
    /// to exact full-context attention until the cool-down passes.
    breaker: CircuitBreaker,
    /// Shared with the HTTP layer: readiness, drain flag, overload.
    pub health: Arc<HealthState>,
    /// Step of the most recent watchdog trip (readiness recovers after
    /// a `breaker_window`-step quiet span).
    last_watchdog_trip: Option<u64>,
    omega: Arc<xla::PjRtBuffer>,
    // Reused step staging buffers (values stay bounded; masked slots
    // carry stale-but-finite data — see DESIGN.md §9 L3).
    buf_k: Vec<f32>,
    buf_v: Vec<f32>,
    buf_mask: Vec<f32>,
    /// Decode S buckets for the configured `n_feat`, cached at startup:
    /// the artifact registry is immutable after load, so there is no
    /// reason to re-derive this every step.
    decode_s_buckets: Vec<usize>,
    /// Worker pool for sharded staging and plane-parallel segment
    /// scoring (`stage_workers > 1`); `None` runs both serially on the
    /// engine thread.
    stage_pool: Option<ThreadPool>,
    /// Durable session journal (`None` unless `journal_dir` is set).
    journal: Option<Journal>,
    /// A `crash@` fault fired this step: the journal is already frozen
    /// at its last durable byte, and the end-of-step hook fails every
    /// live session (their on-disk ADMIT records stay unfinished, so a
    /// restarted engine recovers them).
    crashed: bool,
    // Step-path scratch, reused across steps so the hot loop allocates
    // nothing (cleared before every use; restored after).
    scratch_fused: Vec<SeqId>,
    scratch_radar: Vec<SeqId>,
    scratch_needs: Vec<(SeqId, usize)>,
    scratch_tokens: Vec<i32>,
    scratch_pos: Vec<i32>,
    scratch_alive: Vec<bool>,
    scratch_k_new: Vec<f32>,
    scratch_v_new: Vec<f32>,
    scratch_f_new: Vec<f32>,
}

/// Telemetry for one engine step.
#[derive(Debug, Default, Clone)]
pub struct StepStats {
    pub decoded: usize,
    pub dispatches: usize,
}

/// What `Engine::recover` rebuilt from the journal: one live handle
/// per recovered session (already-terminal ones arrive pre-closed with
/// their `Done` synthesized) and the total token replay volume.
#[derive(Default)]
pub struct RecoveryReport {
    pub sessions: Vec<SessionHandle>,
    pub replayed_tokens: u64,
}

impl Engine {
    pub fn new(rt: Arc<Runtime>, cfg: ServingConfig) -> Result<Self> {
        let blocks = cfg.max_seq_len.div_ceil(crate::kvcache::BLOCK_TOKENS)
            * (cfg.max_batch.max(4) * 4);
        let pool = BlockPool::new(&rt.config, cfg.n_feat, blocks);
        let prefix = PrefixIndex::new(cfg.prefix_cache_mb << 20, pool.block_bytes());
        let omega = rt.omega(cfg.n_feat)?;
        let faults = ActiveFaults::new(cfg.faults.clone());
        let bucket = TokenBucket::new(cfg.admit_rate, cfg.admit_burst);
        let breaker =
            CircuitBreaker::new(cfg.breaker_threshold, cfg.breaker_window, cfg.breaker_cooldown);
        let mut decode_s_buckets: Vec<usize> = rt
            .registry
            .all()
            .iter()
            .filter(|a| a.kind == crate::runtime::ArtifactKind::Decode && a.n_feat == cfg.n_feat)
            .map(|a| a.len)
            .collect();
        decode_s_buckets.sort_unstable();
        decode_s_buckets.dedup();
        let stage_pool =
            (cfg.stage_workers > 1).then(|| ThreadPool::new(cfg.stage_workers, "stage"));
        let metrics = Arc::new(Metrics::new());
        let journal = if cfg.journal_dir.is_empty() {
            None
        } else {
            Some(Journal::open(&cfg.journal_dir, cfg.journal_fsync_every, metrics.clone())?)
        };
        Ok(Self {
            rt,
            cfg,
            pool,
            metrics,
            prefix,
            seqs: BTreeMap::new(),
            pending: VecDeque::new(),
            next_id: 1,
            faults,
            step_no: 0,
            bucket,
            breaker,
            health: Arc::new(HealthState::new()),
            last_watchdog_trip: None,
            omega,
            buf_k: Vec::new(),
            buf_v: Vec::new(),
            buf_mask: Vec::new(),
            decode_s_buckets,
            stage_pool,
            journal,
            crashed: false,
            scratch_fused: Vec::new(),
            scratch_radar: Vec::new(),
            scratch_needs: Vec::new(),
            scratch_tokens: Vec::new(),
            scratch_pos: Vec::new(),
            scratch_alive: Vec::new(),
            scratch_k_new: Vec::new(),
            scratch_v_new: Vec::new(),
            scratch_f_new: Vec::new(),
        })
    }

    pub fn seq(&self, id: SeqId) -> Option<&Sequence> {
        self.seqs.get(&id)
    }

    pub fn active_ids(&self) -> Vec<SeqId> {
        self.seqs.iter().filter(|(_, s)| !s.done).map(|(&i, _)| i).collect()
    }

    pub fn finished(&self) -> Vec<SeqId> {
        self.seqs.iter().filter(|(_, s)| s.done).map(|(&i, _)| i).collect()
    }

    /// No runnable work: nothing queued and nothing mid-decode.
    /// (Finished-but-unremoved legacy sequences don't count.)
    pub fn idle(&self) -> bool {
        self.pending.is_empty() && self.seqs.values().all(|s| s.done)
    }

    pub fn queue_depth(&self) -> usize {
        self.pending.len()
    }

    // -----------------------------------------------------------------
    // Session API
    // -----------------------------------------------------------------

    /// Enqueue a request for admission and return its session handle.
    ///
    /// This is cheap (no prefill): the request waits in a bounded queue
    /// until `step` admits it, so the batcher — not the socket layer —
    /// owns backpressure. A full queue is an explicit rejection the
    /// HTTP surface maps to 429.
    pub fn submit(&mut self, req: GenRequest) -> Result<SessionHandle, SubmitError> {
        let need = req.prompt.len() + req.max_new_tokens;
        if need > self.cfg.max_seq_len {
            self.metrics.inc("requests_rejected");
            return Err(SubmitError::TooLong { need, max: self.cfg.max_seq_len });
        }
        if self.health.draining() {
            self.metrics.inc("requests_rejected");
            return Err(SubmitError::Draining);
        }
        if self.bucket.enabled() {
            // Cost = work this request adds: uncached prefill tokens
            // plus the decode budget it reserves.
            let total = req.prompt.len().saturating_sub(1);
            let cached =
                if self.cfg.prefix_cache && req.prefix_cache && self.reuse_safe_policy() {
                    self.prefix.peek_match_tokens(&req.prompt, total)
                } else {
                    0
                };
            let cost = (total - cached + req.max_new_tokens) as f64;
            if let Err(retry_after_ms) = self.bucket.try_take(cost, Instant::now()) {
                self.metrics.inc("requests_rejected");
                return Err(SubmitError::RateLimited { retry_after_ms });
            }
        }
        // Watermark load-shedding: above the high-water mark on the
        // queue or the KV pool, a strictly lower-priority queued entry
        // is displaced to make room. Queue pressure with no victim
        // falls through to the hard `QueueFull` cap below; KV pressure
        // with no victim rejects outright (admitting would only thrash
        // the preemption path).
        let pct = self.cfg.shed_watermark_pct as usize;
        let queue_hot = self.pending.len() * 100 >= self.cfg.max_pending * pct;
        let kv_hot = self.pool.used_blocks() * 100 >= self.pool.capacity() * pct;
        if queue_hot || kv_hot {
            let victim =
                shed_victim(self.pending.iter().map(|p| (p.id, p.priority())), req.priority);
            match victim {
                Some(vid) => self.shed_pending(vid),
                None if kv_hot => {
                    self.metrics.inc("requests_rejected");
                    return Err(SubmitError::Shed { retry_after_ms: SHED_RETRY_MS });
                }
                None => {}
            }
        }
        if self.pending.len() >= self.cfg.max_pending {
            self.metrics.inc("requests_rejected");
            return Err(SubmitError::QueueFull { depth: self.pending.len() });
        }
        let id = self.next_id;
        self.next_id += 1;
        self.journal_admit(id, &req);
        let events: Channel<SessionEvent> = Channel::new();
        let cancel = Arc::new(AtomicBool::new(false));
        let handle = SessionHandle::new(id, events.clone(), cancel.clone());
        let now = Instant::now();
        let deadline = effective_deadline(req.timeout_ms, self.cfg.timeout_ms, now);
        self.pending.push_back(PendingSession {
            id,
            work: PendingWork::Fresh(req),
            events: Some(events),
            cancel,
            queued_at: now,
            enqueued_at: now,
            deadline,
        });
        self.metrics.inc("requests_submitted");
        self.metrics.set_gauge("queue_depth", self.pending.len() as f64);
        Ok(handle)
    }

    /// Displace one queued session so a higher-priority arrival can be
    /// admitted. The victim holds no KV blocks (it was never admitted),
    /// so this is an event + bookkeeping, not a resource release. The
    /// `shed:` message prefix is load-bearing: the HTTP layer maps it
    /// to 503 + `Retry-After`.
    fn shed_pending(&mut self, id: SeqId) {
        let Some(pos) = self.pending.iter().position(|p| p.id == id) else { return };
        let p = self.pending.remove(pos).expect("position found on this queue just above");
        if let Some(ev) = &p.events {
            ev.send(SessionEvent::Error(
                "shed: displaced by a higher-priority arrival under load; retry later".to_string(),
            ));
            ev.close();
        }
        self.metrics.inc("shed_requests");
        self.metrics.inc("requests_failed");
        self.journal_finish(id, Terminal::Error);
        self.metrics.set_gauge("queue_depth", self.pending.len() as f64);
    }

    /// Whether the configured policy tolerates skipping shared-prefix
    /// prefill chunks. Radar variants rebuild their index from pooled
    /// per-token features (and adopt frozen donor segments), so they
    /// are always safe; fused policies answer via the trait.
    fn reuse_safe_policy(&self) -> bool {
        if crate::policy::is_query_dependent(self.cfg.policy) {
            return true;
        }
        crate::policy::make_policy(&self.cfg, self.rt.config.n_lh()).prefix_reuse_safe()
    }

    /// Move queued sessions into the active set (prefilling them) while
    /// concurrency allows.
    ///
    /// Admission is shortest-uncached-prefill-first, not FIFO: prefix
    /// cache hits owe only their suffix, so serving them first cuts
    /// mean TTFT; cold prompts cannot starve because the pending queue
    /// is bounded (`max_pending`) and drains every step.
    fn admit_pending(&mut self) {
        let active = self.seqs.values().filter(|s| !s.done).count();
        let mut slots = self.cfg.max_batch.saturating_sub(active);
        if slots == 0 || self.pending.is_empty() {
            return;
        }
        let reuse_ok = self.cfg.prefix_cache && self.reuse_safe_policy();
        let costs: Vec<(SeqId, usize)> = self
            .pending
            .iter()
            .map(|p| {
                let toks = p.prefill_tokens();
                let total = toks.len().saturating_sub(1);
                let cached = if reuse_ok && p.wants_prefix_cache() {
                    self.prefix.peek_match_tokens(toks, total)
                } else {
                    0
                };
                (p.id, total - cached)
            })
            .collect();
        for id in admission_order(&costs) {
            if slots == 0 {
                break;
            }
            let pos = self
                .pending
                .iter()
                .position(|p| p.id == id)
                .expect("admission order ids come from the pending queue, unchanged since");
            let p = self
                .pending
                .remove(pos)
                .expect("position found by the search on this queue just above");
            self.metrics.set_gauge("queue_depth", self.pending.len() as f64);
            if p.cancel.load(std::sync::atomic::Ordering::Acquire) {
                // Cancelled while queued: holds no blocks (fresh ones
                // never allocated; preempted ones already freed).
                if let Some(ev) = &p.events {
                    ev.send(SessionEvent::Done {
                        usage: p.terminal_usage(),
                        finish: FinishReason::Cancelled,
                    });
                    ev.close();
                }
                self.metrics.inc("requests_cancelled");
                self.journal_finish(p.id, Terminal::Cancelled);
                continue;
            }
            match p.work {
                PendingWork::Fresh(req) => {
                    self.metrics
                        .observe_us("queue_wait", p.enqueued_at.elapsed().as_secs_f64() * 1e6);
                    let (nl, nh) = (self.rt.config.n_layers, self.rt.config.n_heads);
                    let mut seq = Sequence::new(p.id, req, &self.cfg, nl, nh);
                    seq.emitter = p.events;
                    seq.cancel = p.cancel;
                    seq.queued_at = p.queued_at;
                    seq.deadline = p.deadline;
                    let t0 = Instant::now();
                    let Some(mut seq) = self.prefill_contained(seq) else { continue };
                    self.register_prefix(&seq);
                    seq.prefill_ms = t0.elapsed().as_secs_f64() * 1e3;
                    self.metrics.inc("requests_admitted");
                    self.metrics.observe_us("prefill", seq.prefill_ms * 1e3);
                    self.seqs.insert(seq.id, seq);
                    slots -= 1;
                }
                PendingWork::Resume(seq) => {
                    let t0 = Instant::now();
                    let Some(mut seq) = self.prefill_contained(*seq) else { continue };
                    if let Some(t) = seq.preempted_at.take() {
                        self.metrics
                            .observe_us("preempt_recovery", t.elapsed().as_secs_f64() * 1e6);
                    }
                    seq.prefill_ms += t0.elapsed().as_secs_f64() * 1e3;
                    self.seqs.insert(seq.id, seq);
                    slots -= 1;
                }
            }
        }
    }

    /// Run seed + prefill for one sequence with containment: an error
    /// or panic finishes only this sequence, and KV exhaustion preempts
    /// it (requeue-and-retry). Returns the sequence on success; `None`
    /// means it was consumed by one of those paths.
    fn prefill_contained(&mut self, mut seq: Sequence) -> Option<Sequence> {
        if seq.tokens.is_empty() {
            return Some(seq);
        }
        let r = catch_unwind(AssertUnwindSafe(|| {
            self.seed_from_prefix(&mut seq);
            self.prefill(&mut seq)
        }));
        match r {
            Ok(Ok(())) => Some(seq),
            Ok(Err(e)) => {
                if e.downcast_ref::<CacheExhausted>().is_some() {
                    self.preempt(seq, "prefill");
                } else {
                    self.finish_with_error(seq, &format!("prefill failed: {e}"), true);
                }
                None
            }
            Err(p) => {
                self.finish_with_error(
                    seq,
                    &format!("prefill panicked: {}", panic_msg(p)),
                    true,
                );
                None
            }
        }
    }

    /// Seed `seq.cache` from the longest cached run matching its
    /// prompt, leaving only the suffix for `prefill`. No-op when reuse
    /// is disabled (engine- or request-level) or the policy is
    /// stateful over prefill feedback.
    fn seed_from_prefix(&mut self, seq: &mut Sequence) {
        if !self.cfg.prefix_cache || !seq.prefix_cache || seq.tokens.len() <= BLOCK_TOKENS {
            return;
        }
        let safe = match &seq.policy {
            PolicyHolder::Fused(p) => p.prefix_reuse_safe(),
            PolicyHolder::Radar(_) => true,
        };
        if !safe {
            return;
        }
        // The last prompt token always goes through the first decode
        // step, so never serve the full prompt from cache.
        let limit = seq.tokens.len() - 1;
        let m = self.prefix.probe(&seq.tokens, limit);
        if m.tokens == 0 {
            self.metrics.inc("prefix_misses");
            return;
        }
        seq.cache = SeqCache::seed_from_blocks(&mut self.pool, self.cfg.n_feat, &m.blocks);
        seq.cached_tokens = m.tokens;
        if let PolicyHolder::Radar(rp) = &mut seq.policy {
            rp.donor = m.frozen;
        }
        self.metrics.inc("prefix_hits");
        self.metrics.observe("prefill_tokens_saved", m.tokens as f64);
    }

    /// Register a freshly prefilled prompt's full KV blocks (plus the
    /// Radar segment snapshot, if any) in the prefix index, then
    /// enforce the byte budget and refresh the gauges.
    fn register_prefix(&mut self, seq: &Sequence) {
        if !self.cfg.prefix_cache || !seq.prefix_cache {
            return;
        }
        let full = seq.cache.len() / BLOCK_TOKENS;
        if full > 0 {
            let frozen = match &seq.policy {
                PolicyHolder::Radar(rp) => rp.index.freeze(full * BLOCK_TOKENS).map(Arc::new),
                PolicyHolder::Fused(_) => None,
            };
            // KV content is policy-independent (prefill runs full
            // attention), so every policy may populate the tree even
            // though only reuse-safe ones read from it.
            self.prefix.insert(
                &mut self.pool,
                &seq.tokens[..full * BLOCK_TOKENS],
                &seq.cache.blocks[..full],
                frozen,
            );
            if let Err(e) = self.prefix.evict_to_budget(&mut self.pool) {
                // A corrupted refcount is a logic bug; surface loudly
                // in debug, degrade to a counter in release.
                debug_assert!(false, "prefix eviction failed: {e}");
                self.metrics.inc("prefix_evict_errors");
            }
        }
        self.metrics.set_gauge("prefix_cached_blocks", self.prefix.cached_blocks() as f64);
        self.metrics.set_gauge("prefix_bytes", self.prefix.bytes_used() as f64);
        self.metrics
            .set_gauge("prefix_shared_blocks", self.prefix.shared_blocks(&self.pool) as f64);
    }

    // -----------------------------------------------------------------
    // Durability: journal hooks and crash recovery
    // -----------------------------------------------------------------

    /// Append an ADMIT record for a freshly assigned id (no-op without
    /// a journal). The record stores RESOLVED sampler values — seed,
    /// temperature, greedy — so replay after a restart reproduces the
    /// original stream even if the serving config changed meanwhile.
    fn journal_admit(&self, id: SeqId, req: &GenRequest) {
        let Some(j) = &self.journal else { return };
        let (seed, temperature, greedy) = resolved_sampling(id, req, &self.cfg);
        j.admit(&AdmitRecord {
            id,
            seed,
            temperature,
            greedy,
            prompt: req.prompt.clone(),
            max_new_tokens: req.max_new_tokens,
            stop_token: req.stop_token,
            timeout_ms: req.timeout_ms,
            prefix_cache: req.prefix_cache,
            priority: req.priority,
            teacher: req.teacher.clone(),
        });
    }

    /// Append a FINISH record (no-op without a journal). Every terminal
    /// path routes through here so a restart never re-admits a session
    /// the client already saw finish.
    fn journal_finish(&self, id: SeqId, reason: Terminal) {
        if let Some(j) = &self.journal {
            j.finish(id, reason);
        }
    }

    /// Read-only view of journaled session state, shared with the HTTP
    /// layer for session-status and stream-resume endpoints.
    pub fn journal_mirror(&self) -> Option<SessionMirror> {
        self.journal.as_ref().map(|j| j.mirror())
    }

    pub fn journal(&self) -> Option<&Journal> {
        self.journal.as_ref()
    }

    /// Snapshot engine progress + prefix-index topology and rotate the
    /// journal epoch, bounding what a restart must replay. Called on
    /// the `checkpoint_interval_steps` cadence and once during graceful
    /// drain; errors degrade to a counter (durability is best-effort,
    /// serving is not).
    pub fn checkpoint_now(&mut self) {
        let Some(j) = &self.journal else { return };
        let topo = self.prefix.topology();
        if j.checkpoint(self.next_id, &topo).is_err() {
            self.metrics.inc("journal_checkpoint_errors");
        }
    }

    /// A `crash@` fault fired: freeze the journal at its last durable
    /// byte (exactly what `kill -9` would leave behind) and fail the
    /// offending sequence. The end-of-step hook then fails every other
    /// live session. FINISH records are suppressed by the frozen
    /// journal, so the sessions stay unfinished on disk and a restarted
    /// engine recovers them.
    fn simulate_crash(&mut self, seq: Sequence) {
        if let Some(j) = &self.journal {
            j.simulate_crash();
        }
        self.crashed = true;
        self.metrics.inc("injected_crashes");
        self.finish_with_error(seq, "crash: simulated hard abort", false);
    }

    /// Re-admit every unfinished journaled session after a restart.
    ///
    /// Each session is rebuilt from its ADMIT record (resolved sampler
    /// values pinned), journaled tokens are appended, and the
    /// deterministic sampler is fast-forwarded past them — continued
    /// decode therefore emits exactly the suffix an uncrashed run would
    /// have produced. Rebuilt sequences re-prefill through the
    /// admission queue (warm via the prefix cache), the same path
    /// preemption resumes take. Sessions whose journaled progress is
    /// already terminal get their `Done` synthesized here instead.
    pub fn recover(&mut self) -> RecoveryReport {
        let mut report = RecoveryReport::default();
        let (unfinished, floor) = match &self.journal {
            Some(j) => (j.unfinished_sessions(), j.next_id_floor()),
            None => return report,
        };
        let t0 = Instant::now();
        self.next_id = self.next_id.max(floor);
        for st in unfinished {
            let id = st.admit.id;
            self.next_id = self.next_id.max(id + 1);
            let (nl, nh) = (self.rt.config.n_layers, self.rt.config.n_heads);
            let mut seq = Sequence::new(id, st.admit.to_gen_request(), &self.cfg, nl, nh);
            seq.tokens.extend_from_slice(&st.tokens);
            seq.generated = st.tokens.len();
            seq.logprobs = st.logprobs.clone();
            if seq.teacher.is_none() {
                // One RNG draw per sampled token; teacher-forced
                // sessions never touch the sampler.
                seq.sampler.skip(seq.generated);
            }
            let events: Channel<SessionEvent> = Channel::new();
            let cancel = Arc::new(AtomicBool::new(false));
            let handle = SessionHandle::new(id, events.clone(), cancel.clone());
            seq.emitter = Some(events.clone());
            seq.cancel = cancel.clone();
            let now = Instant::now();
            seq.queued_at = now;
            let deadline = effective_deadline(st.admit.timeout_ms, self.cfg.timeout_ms, now);
            seq.deadline = deadline;
            report.replayed_tokens += st.tokens.len() as u64;
            // Journaled progress may already be terminal (the crash hit
            // between the last STEP and its FINISH): synthesize Done
            // rather than re-admitting a sequence with no work left.
            let done = if st.tokens.len() >= st.admit.max_new_tokens {
                Some(FinishReason::Length)
            } else if st.admit.stop_token.is_some()
                && st.tokens.last() == st.admit.stop_token.as_ref()
            {
                Some(FinishReason::Stop)
            } else {
                None
            };
            if let Some(finish) = done {
                seq.done = true;
                seq.finish = Some(finish);
                events.send(SessionEvent::Done { usage: seq.usage(), finish });
                events.close();
                self.journal_finish(id, Terminal::from(finish));
            } else {
                self.pending.push_back(PendingSession {
                    id,
                    work: PendingWork::Resume(Box::new(seq)),
                    events: Some(events),
                    cancel,
                    queued_at: now,
                    enqueued_at: now,
                    deadline,
                });
            }
            self.metrics.inc("recovered_sessions");
            report.sessions.push(handle);
        }
        self.metrics.add("replay_tokens", report.replayed_tokens);
        self.metrics.set_gauge("queue_depth", self.pending.len() as f64);
        self.metrics.observe("recovery_ms", t0.elapsed().as_secs_f64() * 1e3);
        report
    }

    // -----------------------------------------------------------------
    // Fault handling: containment, preemption, deadlines
    // -----------------------------------------------------------------

    /// Terminal failure for one sequence: free its blocks, emit
    /// `Error`, count it. `contained` marks faults the engine absorbed
    /// (panics / step errors) as opposed to resource verdicts
    /// (preemption budget exhausted).
    fn finish_with_error(&mut self, mut seq: Sequence, msg: &str, contained: bool) {
        if contained {
            self.metrics.inc("contained_errors");
            // Contained faults feed the degradation breaker: a burst of
            // them within the window flips the engine into exact-
            // attention degraded mode.
            self.breaker.record(self.step_no);
        }
        if let Err(e) = seq.cache.free(&mut self.pool) {
            debug_assert!(false, "kv release after failure: {e}");
            self.metrics.inc("kv_release_errors");
        }
        if let Some(em) = &seq.emitter {
            em.send(SessionEvent::Error(msg.to_string()));
            em.close();
        }
        self.metrics.inc("requests_failed");
        self.journal_finish(seq.id, Terminal::Error);
    }

    /// Free this sequence's blocks and requeue it through admission: it
    /// re-prefills its prompt + generated tokens (warm via the prefix
    /// cache) and resumes decoding where it left off. After
    /// `max_preemptions` strikes the request fails with a capacity
    /// error (503) instead.
    fn preempt(&mut self, mut seq: Sequence, phase: &str) {
        if let Err(e) = seq.cache.free(&mut self.pool) {
            debug_assert!(false, "kv release during preemption: {e}");
            self.metrics.inc("kv_release_errors");
        }
        seq.preemptions += 1;
        self.metrics.inc("preemptions");
        if seq.preemptions > self.cfg.max_preemptions {
            let msg = format!(
                "capacity: no kv blocks after {} preemptions ({phase}); retry later",
                seq.preemptions
            );
            self.finish_with_error(seq, &msg, false);
            return;
        }
        // The policy replays deterministically from a fresh state
        // during re-prefill; the sampler is NOT reset — it continues
        // from the last emitted token.
        let (nl, nh) = (self.rt.config.n_layers, self.rt.config.n_heads);
        seq.policy = PolicyHolder::fresh(seq.id, &self.cfg, nl, nh);
        // The staged K/V rows referenced blocks that were just freed;
        // the warm re-admission must restage from scratch.
        seq.staging.invalidate();
        seq.cur_sel = Selection::default();
        seq.cached_tokens = 0;
        seq.preempted_at = Some(Instant::now());
        let entry = PendingSession {
            id: seq.id,
            events: seq.emitter.clone(),
            cancel: seq.cancel.clone(),
            queued_at: seq.queued_at,
            enqueued_at: Instant::now(),
            deadline: seq.deadline,
            work: PendingWork::Resume(Box::new(seq)),
        };
        self.pending.push_back(entry);
        self.metrics.set_gauge("queue_depth", self.pending.len() as f64);
    }

    /// A decode-time allocation failed for `seq` (already detached from
    /// the active set). Pick the global victim — lowest progress,
    /// youngest on ties — among all active sequences including `seq`.
    /// When the victim is someone else, `seq` stays active and retries
    /// the same token next step: the failed step advanced neither its
    /// input stream nor its sampler.
    fn handle_kv_pressure(&mut self, seq: Sequence, phase: &str) {
        let victim = preemption_victim(
            self.seqs
                .iter()
                .filter(|(_, s)| !s.done)
                .map(|(&i, s)| (i, s.generated))
                .chain(std::iter::once((seq.id, seq.generated))),
        )
        .unwrap_or(seq.id);
        if victim == seq.id {
            self.preempt(seq, phase);
        } else {
            self.seqs.insert(seq.id, seq);
            let v = self.seqs.remove(&victim).expect("victim chosen from the active set");
            self.preempt(v, phase);
        }
    }

    /// Finish active sequences and queued sessions whose deadlines
    /// expired (plus queue entries over the queue-wait cap). Active
    /// expiries keep their partial tokens: `reap_finished` delivers
    /// `Done { finish: Timeout }`.
    fn sweep_deadlines(&mut self) {
        if self.cfg.queue_timeout_ms == 0
            && self.pending.iter().all(|p| p.deadline.is_none())
            && self.seqs.values().all(|s| s.deadline.is_none())
        {
            return;
        }
        let now = Instant::now();
        let expired: Vec<SeqId> = self
            .seqs
            .iter()
            .filter(|(_, s)| !s.done && s.deadline.is_some_and(|d| now >= d))
            .map(|(&i, _)| i)
            .collect();
        for id in expired {
            let seq = self.seqs.get_mut(&id).expect("expired id collected from the map above");
            seq.done = true;
            seq.finish = Some(FinishReason::Timeout);
            self.metrics.inc("timeouts");
        }
        let queue_cap = self.cfg.queue_timeout_ms;
        let mut i = 0;
        while i < self.pending.len() {
            let p = &self.pending[i];
            let hit_deadline = p.deadline.is_some_and(|d| now >= d);
            let hit_queue_cap = queue_cap > 0
                && now.duration_since(p.enqueued_at) >= Duration::from_millis(queue_cap);
            if !(hit_deadline || hit_queue_cap) {
                i += 1;
                continue;
            }
            let p = self.pending.remove(i).expect("index bounded by the loop condition");
            if let Some(ev) = &p.events {
                ev.send(SessionEvent::Done {
                    usage: p.terminal_usage(),
                    finish: FinishReason::Timeout,
                });
                ev.close();
            }
            self.metrics.inc("timeouts");
            self.journal_finish(p.id, Terminal::Timeout);
        }
        self.metrics.set_gauge("queue_depth", self.pending.len() as f64);
    }

    /// Drop sequences whose cancel flag flipped, freeing their KV
    /// blocks immediately (before any decode work this step).
    fn sweep_cancelled(&mut self) {
        let cancelled: Vec<SeqId> = self
            .seqs
            .iter()
            .filter(|(_, s)| !s.done && s.is_cancelled())
            .map(|(&i, _)| i)
            .collect();
        for id in cancelled {
            let mut seq =
                self.seqs.remove(&id).expect("cancelled id collected from the live map above");
            seq.cache.free(&mut self.pool).expect("kv block double-free");
            seq.finish = Some(FinishReason::Cancelled);
            if let Some(em) = &seq.emitter {
                em.send(SessionEvent::Done {
                    usage: seq.usage(),
                    finish: FinishReason::Cancelled,
                });
                em.close();
            }
            self.metrics.inc("requests_cancelled");
            self.journal_finish(id, Terminal::Cancelled);
        }
    }

    /// Deliver `Done` for finished session-backed sequences and free
    /// their blocks. Legacy (`add`) sequences are left for `remove`.
    fn reap_finished(&mut self) {
        let done: Vec<SeqId> = self
            .seqs
            .iter()
            .filter(|(_, s)| s.done && s.emitter.is_some())
            .map(|(&i, _)| i)
            .collect();
        for id in done {
            let mut seq =
                self.seqs.remove(&id).expect("finished id collected from the live map above");
            seq.cache.free(&mut self.pool).expect("kv block double-free");
            if let Some(em) = &seq.emitter {
                em.send(SessionEvent::Done {
                    usage: seq.usage(),
                    finish: seq.finish.unwrap_or(FinishReason::Length),
                });
                em.close();
            }
            self.metrics.inc("requests_completed");
            self.journal_finish(id, Terminal::from(seq.finish.unwrap_or(FinishReason::Length)));
        }
    }

    /// Terminal shutdown path: fail every queued and active session and
    /// release all cache blocks. This is NOT the per-sequence error
    /// path — step faults are contained — it is reserved for true
    /// process shutdown (server stop, unrecoverable engine state).
    pub fn fail_all(&mut self, msg: &str) {
        let pending: Vec<PendingSession> = self.pending.drain(..).collect();
        for p in pending {
            if let Some(ev) = &p.events {
                ev.send(SessionEvent::Error(msg.to_string()));
                ev.close();
            }
            self.metrics.inc("requests_failed");
            // No-op on a crash-frozen journal: the session must stay
            // unfinished on disk so a restart can recover it.
            self.journal_finish(p.id, Terminal::Error);
        }
        let ids: Vec<SeqId> = self.seqs.keys().copied().collect();
        for id in ids {
            let mut seq = self.seqs.remove(&id).expect("id taken from the key set just above");
            seq.cache.free(&mut self.pool).expect("kv block double-free");
            if let Some(em) = &seq.emitter {
                em.send(SessionEvent::Error(msg.to_string()));
                em.close();
                self.metrics.inc("requests_failed");
            }
            self.journal_finish(id, Terminal::Error);
        }
        self.prefix.clear(&mut self.pool).expect("kv block double-free");
        self.metrics.set_gauge("queue_depth", 0.0);
        self.metrics.set_gauge("kv_blocks_used", self.pool.used_blocks() as f64);
    }

    /// Admit a request: allocate the sequence and run prefill on the
    /// prompt (if any). Returns the sequence id.
    pub fn add(&mut self, req: GenRequest) -> Result<SeqId> {
        let id = self.next_id;
        self.next_id += 1;
        self.journal_admit(id, &req);
        let (nl, nh) = (self.rt.config.n_layers, self.rt.config.n_heads);
        let mut seq = Sequence::new(id, req, &self.cfg, nl, nh);
        let t0 = Instant::now();
        if !seq.tokens.is_empty() {
            self.seed_from_prefix(&mut seq);
            self.prefill(&mut seq)?;
            self.register_prefix(&seq);
        }
        seq.prefill_ms = t0.elapsed().as_secs_f64() * 1e3;
        self.metrics.inc("requests_admitted");
        self.metrics.observe_us("prefill", seq.prefill_ms * 1e3);
        self.seqs.insert(id, seq);
        Ok(id)
    }

    /// Remove a finished sequence, freeing its cache blocks.
    pub fn remove(&mut self, id: SeqId) -> Option<GenResult> {
        let mut seq = self.seqs.remove(&id)?;
        seq.cache.free(&mut self.pool).expect("kv block double-free");
        self.journal_finish(id, Terminal::from(seq.finish.unwrap_or(FinishReason::Length)));
        Some(seq.result())
    }

    // -----------------------------------------------------------------
    // Prefill
    // -----------------------------------------------------------------

    /// Prefill covers tokens [0, P-1): the LAST prompt token is left
    /// for the first decode step, whose logits produce the first
    /// generated/evaluated token (standard prefill/decode handoff).
    ///
    /// Warm start: when `seed_from_prefix` already populated the cache
    /// with the first `cache.len()` tokens, only the suffix is
    /// dispatched. Chunks stay on the absolute `chunk`-token grid, so
    /// past the (possibly partial) seam chunk a warm run issues the
    /// same dispatches over the same inputs as a cold one.
    fn prefill(&mut self, seq: &mut Sequence) -> Result<()> {
        let rt = Arc::clone(&self.rt);
        let chunk = rt.registry.prefill_chunk;
        let (l, h, dh) = (rt.config.n_layers, rt.config.n_heads, rt.config.d_head);
        let total = seq.tokens.len() - 1;
        debug_assert!(seq.cache.len() <= total, "seeded past the prefill range");
        self.metrics.add("prefill_tokens", (total - seq.cache.len()) as u64);
        // Whole chunks via the prefill artifact; a trailing partial
        // chunk is PADDED to the chunk size and run as one dispatch
        // (causality makes real positions independent of the padding,
        // whose outputs are simply not appended — §Perf L3-1: this
        // replaced up to chunk-1 sequential decode dispatches). A
        // mid-grid warm start reuses the same padding path.
        while seq.cache.len() < total {
            let t0 = seq.cache.len();
            let t1 = ((t0 / chunk + 1) * chunk).min(total);
            let real = t1 - t0;
            let meta = rt.registry.resolve_prefill(t0, self.cfg.n_feat)?;
            let p = meta.len;
            let mut past_k = vec![0.0f32; l * h * p * dh];
            let mut past_v = vec![0.0f32; l * h * p * dh];
            let mut pmask = vec![NEG; p];
            if t0 > 0 {
                seq.cache.gather_past(&self.pool, 0, t0, p, &mut past_k, &mut past_v);
            }
            for m in pmask.iter_mut().take(t0) {
                *m = 0.0;
            }
            let mut toks: Vec<i32> = seq.tokens[t0..t1].to_vec();
            toks.resize(chunk, 0); // pad the tail chunk
            let out = rt.prefill(
                meta, &self.omega, &toks, t0 as i32, &past_k, &past_v, &pmask,
            )?;
            seq.cache
                .append_chunk(&mut self.pool, real, chunk, &out.k_c, &out.v_c, &out.feat_c)?;
            // Policy feedback. Policies assume colsum rows of width
            // p + (t1 - t0); when the chunk was padded, re-pack the
            // rows to drop the padded keys' columns.
            match &mut seq.policy {
                PolicyHolder::Fused(p_obj) => {
                    let ctx = SelectCtx {
                        pool: &self.pool,
                        seq: &seq.cache,
                        t: t1,
                        cfg: &self.cfg,
                    };
                    if real == chunk {
                        p_obj.on_prefill(&ctx, &out.colsum, p, t0, t1);
                    } else {
                        let src_w = p + chunk;
                        let dst_w = p + real;
                        let mut trimmed = vec![0.0f32; l * h * dst_w];
                        for plane in 0..l * h {
                            trimmed[plane * dst_w..(plane + 1) * dst_w]
                                .copy_from_slice(&out.colsum[plane * src_w..plane * src_w + dst_w]);
                        }
                        p_obj.on_prefill(&ctx, &trimmed, p, t0, t1);
                    }
                }
                PolicyHolder::Radar(_) => {}
            }
        }
        // Radar: build the initial segment structure once (adopting any
        // frozen donor segments from the prefix cache).
        if let PolicyHolder::Radar(rp) = &mut seq.policy {
            rp.force_restructure(&seq.cache, &self.pool);
        }
        Ok(())
    }

    // -----------------------------------------------------------------
    // Decode: public step API
    // -----------------------------------------------------------------

    /// One engine step: observe cancellations and expired deadlines
    /// (freeing blocks before any decode work), admit queued sessions,
    /// advance every runnable sequence by one token, then deliver
    /// terminal events. Fused sequences are batched; radar sequences
    /// run per-layer. Per-sequence faults are contained here; `Err`
    /// from this method means the engine itself is broken.
    pub fn step(&mut self) -> Result<StepStats> {
        let mut stats = StepStats::default();
        self.step_no += 1;
        let step_no = self.step_no;
        if let Some(ms) = self.faults.take_slow(step_no) {
            std::thread::sleep(Duration::from_millis(ms));
            self.metrics.inc("injected_slow_steps");
        }
        // Degradation breaker: advance its step clock and surface
        // transitions as metrics. While degraded, every Radar sequence
        // runs exact full-context attention (`force_full`); fused
        // policies are untouched — their selection is query-independent
        // and was never the anomaly source.
        match self.breaker.tick(step_no) {
            Some(BreakerTransition::Entered) => self.metrics.inc("degraded_mode_entered"),
            Some(BreakerTransition::Exited) => self.metrics.inc("degraded_mode_exited"),
            None => {}
        }
        let degraded = self.breaker.degraded();
        self.metrics.set_gauge("degraded_mode", if degraded { 1.0 } else { 0.0 });
        // Watchdog readiness recovers after a quiet window.
        if let Some(t) = self.last_watchdog_trip {
            if step_no >= t + self.cfg.breaker_window {
                self.health.set_watchdog_unquiet(false);
                self.last_watchdog_trip = None;
            }
        }
        self.sweep_cancelled();
        self.sweep_deadlines();
        self.admit_pending();
        // Propagate after admission so a sequence admitted this step
        // decodes its first token under the current mode.
        for seq in self.seqs.values_mut() {
            if let PolicyHolder::Radar(rp) = &mut seq.policy {
                rp.force_full = degraded;
            }
        }
        // Partition runnable sequences by pipeline into reusable
        // scratch vectors (the step path allocates nothing).
        let mut fused = std::mem::take(&mut self.scratch_fused);
        let mut radar = std::mem::take(&mut self.scratch_radar);
        fused.clear();
        radar.clear();
        for (&id, s) in &self.seqs {
            if s.done {
                continue;
            }
            match s.policy {
                PolicyHolder::Fused(_) => fused.push(id),
                PolicyHolder::Radar(_) => radar.push(id),
            }
        }
        if fused.is_empty() && radar.is_empty() {
            self.scratch_fused = fused;
            self.scratch_radar = radar;
            // Still deliver terminal events (e.g. queue-less timeouts).
            self.reap_finished();
            self.metrics.set_gauge("kv_blocks_used", self.pool.used_blocks() as f64);
            self.publish_health();
            return Ok(stats);
        }
        if !fused.is_empty() {
            stats.merge(self.step_fused_batch(&fused, step_no)?);
        }
        for &id in &radar {
            // May have been preempted as another row's KV victim.
            let Some(mut seq) = self.seqs.remove(&id) else { continue };
            if self.faults.take_crash(step_no, id) {
                self.simulate_crash(seq);
                continue;
            }
            let inject_panic = self.faults.take_panic(step_no, id);
            let t_watch = Instant::now();
            let r = catch_unwind(AssertUnwindSafe(|| {
                if inject_panic {
                    panic!("injected step panic (seq {id})");
                }
                self.advance_radar(&mut seq, step_no)
            }));
            match r {
                Ok(Ok(())) => {
                    if let Some(ms) = self.watchdog_overrun(t_watch) {
                        self.trip_watchdog(seq, "radar decode", ms);
                    } else {
                        self.seqs.insert(id, seq);
                        stats.decoded += 1;
                        stats.dispatches += 2 * self.rt.config.n_layers;
                    }
                }
                Ok(Err(e)) if e.downcast_ref::<CacheExhausted>().is_some() => {
                    self.handle_kv_pressure(seq, "decode");
                }
                Ok(Err(e)) => {
                    self.finish_with_error(seq, &format!("decode failed: {e}"), true);
                }
                Err(p) => {
                    self.finish_with_error(
                        seq,
                        &format!("decode panicked: {}", panic_msg(p)),
                        true,
                    );
                }
            }
        }
        self.scratch_fused = fused;
        self.scratch_radar = radar;
        self.reap_finished();
        if self.crashed {
            // A `crash@` fault froze the journal mid-step; take the
            // whole engine down the way a hard kill would. FINISH
            // suppression keeps every live session recoverable.
            self.crashed = false;
            self.fail_all("crash: simulated hard abort (restart to recover)");
        }
        if self.cfg.checkpoint_interval_steps > 0
            && step_no % self.cfg.checkpoint_interval_steps == 0
        {
            self.checkpoint_now();
        }
        self.metrics.set_gauge("kv_blocks_used", self.pool.used_blocks() as f64);
        self.metrics
            .set_gauge("prefix_shared_blocks", self.prefix.shared_blocks(&self.pool) as f64);
        self.publish_health();
        Ok(stats)
    }

    /// Whether the breaker currently holds the engine in exact-
    /// attention degraded mode.
    pub fn degraded(&self) -> bool {
        self.breaker.degraded()
    }

    /// Publish end-of-step readiness inputs shared with `/readyz`.
    fn publish_health(&self) {
        let pct = self.cfg.shed_watermark_pct as usize;
        let kv_hot = self.pool.used_blocks() * 100 >= self.pool.capacity() * pct;
        self.health.set_overloaded(kv_hot);
    }

    /// `Some(elapsed_ms)` when the watchdog is armed and a sequence's
    /// step body ran past its budget without yielding control.
    fn watchdog_overrun(&self, t0: Instant) -> Option<u64> {
        if self.cfg.watchdog_ms == 0 {
            return None;
        }
        let ms = t0.elapsed().as_millis() as u64;
        (ms >= self.cfg.watchdog_ms).then_some(ms)
    }

    /// One sequence monopolized the step loop past `watchdog_ms`:
    /// record the trip, mark readiness unquiet, and force-finish the
    /// offender through the containment path (frees its blocks and
    /// feeds the degradation breaker).
    fn trip_watchdog(&mut self, seq: Sequence, phase: &str, elapsed_ms: u64) {
        self.note_watchdog_trip();
        let msg = format!(
            "watchdog: {phase} stalled for {elapsed_ms} ms (budget {} ms); sequence force-finished",
            self.cfg.watchdog_ms
        );
        self.finish_with_error(seq, &msg, true);
    }

    /// Trip bookkeeping shared by both pipelines' watchdog paths.
    fn note_watchdog_trip(&mut self) {
        self.metrics.inc("watchdog_trips");
        self.last_watchdog_trip = Some(self.step_no);
        self.health.set_watchdog_unquiet(true);
    }

    /// Run all queued + active sequences to completion; returns the
    /// finished results of legacy (`add`) sequences. Session results
    /// are delivered through their handles instead.
    pub fn run_to_completion(&mut self) -> Result<Vec<GenResult>> {
        while !self.idle() {
            self.step()?;
        }
        let ids = self.finished();
        Ok(ids.into_iter().filter_map(|i| self.remove(i)).collect())
    }

    // -----------------------------------------------------------------
    // Fused pipeline (batched)
    // -----------------------------------------------------------------

    fn step_fused_batch(&mut self, ids: &[SeqId], step_no: u64) -> Result<StepStats> {
        let mut stats = StepStats::default();
        // Compute selections + needed S per sequence. The selection is
        // stored on the sequence (`cur_sel`) so the staging and policy-
        // feedback paths read it without a per-step map.
        let mut needs = std::mem::take(&mut self.scratch_needs);
        needs.clear();
        for &id in ids {
            let Some(mut seq) = self.seqs.remove(&id) else { continue };
            match catch_unwind(AssertUnwindSafe(|| self.select_fused(&mut seq))) {
                Ok(sel) => {
                    needs.push((id, sel.max_len().max(1)));
                    seq.cur_sel = sel;
                    self.seqs.insert(id, seq);
                }
                Err(p) => {
                    self.finish_with_error(
                        seq,
                        &format!("selection panicked: {}", panic_msg(p)),
                        true,
                    );
                }
            }
        }
        if needs.is_empty() {
            self.scratch_needs = needs;
            return Ok(stats);
        }
        let groups = group_by_bucket(&needs, &self.decode_s_buckets, self.cfg.max_batch);
        self.scratch_needs = needs;
        let rt = Arc::clone(&self.rt);
        for g in groups {
            let b_need = g.seq_ids.len();
            let meta = match rt.registry.resolve_decode(b_need, g.bucket_s, self.cfg.n_feat) {
                Ok(m) => m,
                Err(e) => {
                    // No compiled artifact serves this group (e.g. a
                    // selection outgrew every S bucket): fail its
                    // members, leave other groups running.
                    let msg = format!("decode dispatch unavailable: {e}");
                    self.fail_group(&g.seq_ids, &msg);
                    continue;
                }
            };
            match self.dispatch_fused_group(&g.seq_ids, meta, step_no) {
                Ok(decoded) => {
                    stats.decoded += decoded;
                    stats.dispatches += 1;
                }
                Err(e) => {
                    // The shared dispatch failed: every row in this
                    // group is suspect, but other groups keep running.
                    let msg = format!("decode dispatch failed: {e}");
                    self.fail_group(&g.seq_ids, &msg);
                }
            }
        }
        Ok(stats)
    }

    /// Fail every still-active member of one batch group.
    fn fail_group(&mut self, ids: &[SeqId], msg: &str) {
        for &id in ids {
            let Some(seq) = self.seqs.remove(&id) else { continue };
            self.finish_with_error(seq, msg, true);
        }
    }

    /// Run the policy's per-step selection for one fused sequence.
    fn select_fused(&self, seq: &mut Sequence) -> Selection {
        let ctx = SelectCtx {
            pool: &self.pool,
            seq: &seq.cache,
            t: seq.cache.len(),
            cfg: &self.cfg,
        };
        match &mut seq.policy {
            PolicyHolder::Fused(p) => p.select(&ctx),
            PolicyHolder::Radar(_) => unreachable!("radar sequences use the per-layer pipeline"),
        }
    }

    /// Dispatch one compatible batch group; returns how many rows
    /// finished. A fault in one row (staging panic, append failure, KV
    /// exhaustion) masks or preempts only that sequence — the batch
    /// rows are independent, so survivors' outputs are unchanged.
    fn dispatch_fused_group(
        &mut self,
        ids: &[SeqId],
        meta: &crate::runtime::ArtifactMeta,
        step_no: u64,
    ) -> Result<usize> {
        let (l, h, dh) =
            (self.rt.config.n_layers, self.rt.config.n_heads, self.rt.config.d_head);
        let vocab = self.rt.config.vocab;
        let (b, s) = (meta.batch, meta.len);
        let row_kv = l * h * s * dh;
        let row_mask = l * h * s;
        self.buf_k.resize(b * row_kv, 0.0);
        self.buf_v.resize(b * row_kv, 0.0);
        self.buf_mask.resize(b * row_mask, 0.0);
        let mut tokens = std::mem::take(&mut self.scratch_tokens);
        let mut pos = std::mem::take(&mut self.scratch_pos);
        let mut alive = std::mem::take(&mut self.scratch_alive);
        tokens.clear();
        tokens.resize(b, 0);
        pos.clear();
        pos.resize(b, 0);
        alive.clear();
        alive.resize(ids.len(), true);
        // Stage rows. A failed row becomes a fully masked ghost row
        // (same treatment as batch padding), so the dispatch stays
        // valid for the others.
        for (bi, &id) in ids.iter().enumerate() {
            if self.faults.take_crash(step_no, id) {
                alive[bi] = false;
                self.buf_mask[bi * row_mask..(bi + 1) * row_mask].fill(NEG);
                if let Some(seq) = self.seqs.remove(&id) {
                    self.simulate_crash(seq);
                }
                continue;
            }
            let inject_panic = self.faults.take_panic(step_no, id);
            // A scripted stall is attributed to the first row staged at
            // the armed step, so the watchdog sees one clear offender.
            let stall_ms = self.faults.take_stall(step_no);
            if stall_ms.is_some() {
                self.metrics.inc("injected_stalls");
            }
            let t_watch = Instant::now();
            let staged = catch_unwind(AssertUnwindSafe(|| {
                if inject_panic {
                    panic!("injected step panic (seq {id})");
                }
                if let Some(ms) = stall_ms {
                    std::thread::sleep(Duration::from_millis(ms));
                }
                self.stage_fused_row(id, bi, meta)
            }));
            let mut fail = match staged {
                Ok(Ok((tok, p))) => {
                    tokens[bi] = tok;
                    pos[bi] = p;
                    None
                }
                Ok(Err(e)) => Some(format!("decode staging failed: {e}")),
                Err(p) => Some(format!("decode staging panicked: {}", panic_msg(p))),
            };
            if fail.is_none() {
                if let Some(ms) = self.watchdog_overrun(t_watch) {
                    self.note_watchdog_trip();
                    fail = Some(format!(
                        "watchdog: fused staging stalled for {ms} ms (budget {} ms); \
                         sequence force-finished",
                        self.cfg.watchdog_ms
                    ));
                }
            }
            if let Some(msg) = fail {
                alive[bi] = false;
                self.buf_mask[bi * row_mask..(bi + 1) * row_mask].fill(NEG);
                if let Some(seq) = self.seqs.remove(&id) {
                    self.finish_with_error(seq, &msg, true);
                }
            }
        }
        if alive.iter().all(|a| !*a) {
            self.scratch_tokens = tokens;
            self.scratch_pos = pos;
            self.scratch_alive = alive;
            return Ok(0);
        }
        // Pad ghost rows (bi >= ids.len()): fully masked.
        for bi in ids.len()..b {
            self.buf_mask[bi * row_mask..(bi + 1) * row_mask].fill(NEG);
        }
        let t_dispatch = Instant::now();
        let out = self.metrics.time("decode_dispatch", || {
            self.rt
                .decode(meta, &self.omega, &tokens, &pos, &self.buf_k, &self.buf_v, &self.buf_mask)
        });
        self.scratch_tokens = tokens;
        self.scratch_pos = pos;
        let out = match out {
            Ok(o) => o,
            Err(e) => {
                self.scratch_alive = alive;
                return Err(e);
            }
        };
        let n_alive = alive.iter().filter(|a| **a).count();
        let dispatch_share = t_dispatch.elapsed().as_secs_f64() * 1e3 / n_alive as f64;
        // Distribute outputs.
        let kv_row = l * h * dh;
        let feat_row = l * h * meta.n_feat;
        let probs_row = l * h * (s + 1);
        let mut decoded = 0usize;
        for (bi, &id) in ids.iter().enumerate() {
            if !alive[bi] {
                continue;
            }
            // May have been preempted as an earlier row's KV victim.
            let Some(mut seq) = self.seqs.remove(&id) else { continue };
            let t0 = Instant::now();
            let inject_alloc = self.faults.take_alloc(step_no, id);
            let row = FusedRowOut {
                logits: &out.logits[bi * vocab..(bi + 1) * vocab],
                k_new: &out.k_new[bi * kv_row..(bi + 1) * kv_row],
                v_new: &out.v_new[bi * kv_row..(bi + 1) * kv_row],
                feat_new: &out.feat_new[bi * feat_row..(bi + 1) * feat_row],
                probs: &out.probs[bi * probs_row..(bi + 1) * probs_row],
                s,
            };
            let r = catch_unwind(AssertUnwindSafe(|| {
                self.finish_fused_row(&mut seq, &row, inject_alloc)
            }));
            match r {
                Ok(Ok(())) => {
                    seq.decode_ms += dispatch_share + t0.elapsed().as_secs_f64() * 1e3;
                    self.seqs.insert(id, seq);
                    decoded += 1;
                }
                Ok(Err(e)) if e.downcast_ref::<CacheExhausted>().is_some() => {
                    self.handle_kv_pressure(seq, "decode");
                }
                Ok(Err(e)) => {
                    self.finish_with_error(seq, &format!("decode failed: {e}"), true);
                }
                Err(p) => {
                    self.finish_with_error(
                        seq,
                        &format!("decode panicked: {}", panic_msg(p)),
                        true,
                    );
                }
            }
        }
        self.scratch_alive = alive;
        self.metrics.add("tokens_decoded", decoded as u64);
        Ok(decoded)
    }

    /// Stage one batch row's input token, position, gathered K/V and
    /// mask into the shared buffers; returns (token, position).
    ///
    /// K/V rows route through the sequence's incremental staging arena:
    /// only slots whose selection changed since the previous step are
    /// re-gathered from the paged cache (`stage_delta`); a cold or
    /// invalidated arena falls back to a full coalesced gather. With a
    /// staging pool configured, planes are sharded across workers.
    fn stage_fused_row(
        &mut self,
        id: SeqId,
        bi: usize,
        meta: &crate::runtime::ArtifactMeta,
    ) -> Result<(i32, i32)> {
        let (l, h, dh) =
            (self.rt.config.n_layers, self.rt.config.n_heads, self.rt.config.d_head);
        let s = meta.len;
        let row_kv = l * h * s * dh;
        let row_mask = l * h * s;
        let delta = self.cfg.stage_delta;
        let seq = self.seqs.get_mut(&id).ok_or_else(|| anyhow!("seq {id} not active"))?;
        let t = seq.cache.len();
        let tok = seq.next_input().ok_or_else(|| anyhow!("seq {id} has no input"))?;
        let Sequence { cache, staging, cur_sel, .. } = seq;
        let dst_k = &mut self.buf_k[bi * row_kv..(bi + 1) * row_kv];
        let dst_v = &mut self.buf_v[bi * row_kv..(bi + 1) * row_kv];
        let dst_m = &mut self.buf_mask[bi * row_mask..(bi + 1) * row_mask];
        let t0 = Instant::now();
        let st = match &self.stage_pool {
            Some(tp) => stage_planes_sharded(
                tp,
                self.cfg.stage_workers,
                &mut staging.planes,
                0,
                h,
                cache,
                &self.pool,
                &cur_sel.per_plane,
                s,
                dst_k,
                dst_v,
                dst_m,
                delta,
                NEG,
            ),
            None => stage_planes_serial(
                &mut staging.planes,
                0,
                h,
                cache,
                &self.pool,
                &cur_sel.per_plane,
                s,
                dst_k,
                dst_v,
                dst_m,
                delta,
                NEG,
            ),
        };
        self.metrics.observe("stage_ms", t0.elapsed().as_secs_f64() * 1e3);
        self.flush_stage_stats(&st);
        Ok((tok, t as i32))
    }

    /// Fold one row/step's staging telemetry into the registry.
    fn flush_stage_stats(&self, st: &StageStats) {
        self.metrics.add("staged_bytes_full", st.bytes_full);
        self.metrics.add("staged_bytes_delta", st.bytes_delta);
        self.metrics.add("stage_delta_hits", st.delta_hits);
        self.metrics.add("stage_full_restages", st.full_restages);
    }

    /// Consume one batch row's output: append KV, feed the policy,
    /// sample/emit the token. `inject_alloc` simulates KV exhaustion
    /// before any state is touched (fault-injection hook).
    fn finish_fused_row(
        &mut self,
        seq: &mut Sequence,
        row: &FusedRowOut,
        inject_alloc: bool,
    ) -> Result<()> {
        if inject_alloc {
            return Err(CacheExhausted {
                blocks: self.pool.capacity(),
                tokens: self.pool.capacity() * BLOCK_TOKENS,
            }
            .into());
        }
        seq.cache.append(&mut self.pool, row.k_new, row.v_new, row.feat_new)?;
        {
            let Sequence { cache, policy, cur_sel, .. } = &mut *seq;
            let ctx = SelectCtx {
                pool: &self.pool,
                seq: cache,
                t: cache.len(),
                cfg: &self.cfg,
            };
            if let PolicyHolder::Fused(p) = policy {
                p.on_decode(&ctx, cur_sel, row.probs, row.s);
            }
        }
        self.finish_token(seq, row.logits);
        Ok(())
    }

    /// Single-sequence fused step (kept for the unbatched API surface;
    /// exercised by unit paths and debugging tools).
    #[allow(dead_code)]
    fn fused_step_one(&mut self, seq: &mut Sequence, tok: i32, pos: usize) -> Result<()> {
        let sel = {
            let ctx = SelectCtx {
                pool: &self.pool,
                seq: &seq.cache,
                t: seq.cache.len(),
                cfg: &self.cfg,
            };
            match &mut seq.policy {
                PolicyHolder::Fused(p) => p.select(&ctx),
                _ => unreachable!(),
            }
        };
        let rt = Arc::clone(&self.rt);
        let meta = rt.registry.resolve_decode(1, sel.max_len().max(1), self.cfg.n_feat)?;
        let (l, h, dh, s) =
            (rt.config.n_layers, rt.config.n_heads, rt.config.d_head, meta.len);
        self.buf_k.resize(l * h * s * dh, 0.0);
        self.buf_v.resize(l * h * s * dh, 0.0);
        self.buf_mask.resize(l * h * s, 0.0);
        let st = stage_planes_serial(
            &mut seq.staging.planes,
            0,
            h,
            &seq.cache,
            &self.pool,
            &sel.per_plane,
            s,
            &mut self.buf_k,
            &mut self.buf_v,
            &mut self.buf_mask,
            self.cfg.stage_delta,
            NEG,
        );
        self.flush_stage_stats(&st);
        let out = rt.decode(
            meta, &self.omega, &[tok], &[pos as i32],
            &self.buf_k, &self.buf_v, &self.buf_mask,
        )?;
        seq.cache.append(&mut self.pool, &out.k_new, &out.v_new, &out.feat_new)?;
        let ctx = SelectCtx { pool: &self.pool, seq: &seq.cache, t: seq.cache.len(), cfg: &self.cfg };
        if let PolicyHolder::Fused(p) = &mut seq.policy {
            p.on_decode(&ctx, &sel, &out.probs, s);
        }
        Ok(())
    }

    // -----------------------------------------------------------------
    // Per-layer (Radar) pipeline
    // -----------------------------------------------------------------

    fn advance_radar(&mut self, seq: &mut Sequence, step_no: u64) -> Result<()> {
        let pos = seq.cache.len();
        let tok = match seq.next_input() {
            Some(t) => t,
            None => {
                seq.done = true;
                return Ok(());
            }
        };
        if self.faults.take_alloc(step_no, seq.id) {
            return Err(CacheExhausted {
                blocks: self.pool.capacity(),
                tokens: self.pool.capacity() * BLOCK_TOKENS,
            }
            .into());
        }
        if let Some(ms) = self.faults.take_stall(step_no) {
            self.metrics.inc("injected_stalls");
            std::thread::sleep(Duration::from_millis(ms));
        }
        if self.faults.take_nan(step_no, seq.id) {
            // Poison the Radar segment summaries in place: this step's
            // selection sees NaN scores and must fall back to exact
            // attention; a later restructure rebuilds clean summaries
            // from the untouched per-token features.
            if let PolicyHolder::Radar(rp) = &mut seq.policy {
                rp.index.poison_with_nan();
            }
            self.metrics.inc("injected_nans");
        }
        let t0 = Instant::now();
        let logits = self.radar_step_logits(seq, tok, pos)?;
        self.finish_token(seq, &logits);
        seq.decode_ms += t0.elapsed().as_secs_f64() * 1e3;
        self.metrics.inc("tokens_decoded");
        Ok(())
    }

    /// The per-layer pipeline for one token; returns final logits.
    /// Gathers route through the sequence's incremental staging arena
    /// (delta gathers at steady state); with a staging pool configured,
    /// both segment scoring and plane staging shard across workers.
    fn radar_step_logits(&mut self, seq: &mut Sequence, tok: i32, pos: usize) -> Result<Vec<f32>> {
        let rt = Arc::clone(&self.rt);
        let (l_n, h_n, dh, nf) =
            (rt.config.n_layers, rt.config.n_heads, rt.config.d_head, self.cfg.n_feat);
        let qkv_meta = rt.registry.resolve_qkv(1, nf)?;
        let delta = self.cfg.stage_delta;
        let mut x = embed(&rt, &[tok]);
        let mut k_all = std::mem::take(&mut self.scratch_k_new);
        let mut v_all = std::mem::take(&mut self.scratch_v_new);
        let mut f_all = std::mem::take(&mut self.scratch_f_new);
        k_all.resize(l_n * h_n * dh, 0.0);
        v_all.resize(l_n * h_n * dh, 0.0);
        f_all.resize(l_n * h_n * nf, 0.0);
        let mut anom_planes = 0u32;
        let mut stage_st = StageStats::default();
        let mut stage_s = 0f64; // seconds spent staging this step
        for li in 0..l_n {
            let q_out = self.metrics.time("qkv_dispatch", || {
                rt.qkv(qkv_meta, li, &self.omega, &x, &[pos as i32])
            })?;
            // Selection with this layer's phi(q), plane-parallel when a
            // staging pool is configured.
            let (sel_planes, s_need) = {
                let rp = match &mut seq.policy {
                    PolicyHolder::Radar(rp) => rp,
                    _ => unreachable!(),
                };
                let planes = rp.select_layer_with(
                    self.stage_pool.as_ref(),
                    &self.pool,
                    &seq.cache,
                    &self.cfg,
                    li,
                    &q_out.phi_q,
                    &q_out.q,
                );
                anom_planes += rp.anomalous_planes;
                let need = planes.iter().map(Vec::len).max().unwrap_or(0).max(1);
                (planes, need)
            };
            let am_meta = rt.registry.resolve_attn_mlp(1, s_need)?;
            let s = am_meta.len;
            self.buf_k.resize(h_n * s * dh, 0.0);
            self.buf_v.resize(h_n * s * dh, 0.0);
            self.buf_mask.resize(h_n * s, 0.0);
            let t_stage = Instant::now();
            {
                let Sequence { cache, staging, .. } = &mut *seq;
                let layer_planes = &mut staging.planes[li * h_n..(li + 1) * h_n];
                let st = match &self.stage_pool {
                    Some(tp) => stage_planes_sharded(
                        tp,
                        self.cfg.stage_workers,
                        layer_planes,
                        li * h_n,
                        h_n,
                        cache,
                        &self.pool,
                        &sel_planes,
                        s,
                        &mut self.buf_k,
                        &mut self.buf_v,
                        &mut self.buf_mask,
                        delta,
                        NEG,
                    ),
                    None => stage_planes_serial(
                        layer_planes,
                        li * h_n,
                        h_n,
                        cache,
                        &self.pool,
                        &sel_planes,
                        s,
                        &mut self.buf_k,
                        &mut self.buf_v,
                        &mut self.buf_mask,
                        delta,
                        NEG,
                    ),
                };
                stage_st.merge(&st);
            }
            stage_s += t_stage.elapsed().as_secs_f64();
            let am_out = self.metrics.time("attnmlp_dispatch", || {
                rt.attn_mlp(
                    am_meta, li, &x, &q_out.q, &q_out.k, &q_out.v,
                    &self.buf_k, &self.buf_v, &self.buf_mask,
                )
            })?;
            x = am_out.x;
            // Stash this layer's new k/v/feat for the append below.
            k_all[li * h_n * dh..(li + 1) * h_n * dh].copy_from_slice(&q_out.k);
            v_all[li * h_n * dh..(li + 1) * h_n * dh].copy_from_slice(&q_out.v);
            f_all[li * h_n * nf..(li + 1) * h_n * nf].copy_from_slice(&q_out.phi_k);
        }
        self.metrics.observe("stage_ms", stage_s * 1e3);
        self.flush_stage_stats(&stage_st);
        let appended = seq.cache.append(&mut self.pool, &k_all, &v_all, &f_all);
        self.scratch_k_new = k_all;
        self.scratch_v_new = v_all;
        self.scratch_f_new = f_all;
        appended?;
        if let PolicyHolder::Radar(rp) = &mut seq.policy {
            rp.on_grow(&self.pool, &seq.cache); // Alg. 1 line 8
        }
        if anom_planes > 0 {
            // One or more (layer, head) planes saw a non-finite segment
            // summary or score and fell back to exact full-context
            // attention for this step. Finite output, degraded speed —
            // and a breaker event, so a burst flips the whole engine.
            self.metrics.inc("anomaly_fallbacks");
            self.metrics.add("anomalous_planes", anom_planes as u64);
            self.breaker.record(self.step_no);
        }
        Ok(head(&rt, &rt.config, &x))
    }

    // -----------------------------------------------------------------
    // Token bookkeeping shared by both pipelines
    // -----------------------------------------------------------------

    fn finish_token(&self, seq: &mut Sequence, logits: &[f32]) {
        // Last-line defense: never let a non-finite logit reach the
        // sampler or the log-prob bookkeeping (the bit-pattern argmax
        // and `ln` both misbehave on NaN).
        let mut repaired: Vec<f32>;
        let logits = if logits.iter().all(|x| x.is_finite()) {
            logits
        } else {
            repaired = logits.to_vec();
            sanitize_logits(&mut repaired);
            self.metrics.inc("logit_sanitizations");
            &repaired
        };
        let pos = seq.cache.len(); // position of the NEXT token
        let mut emitted: Option<(i32, f64)> = None;
        if let Some(teacher) = seq.teacher.clone() {
            // Teacher forcing: the next token is fixed; record log-prob.
            let step = seq.generated;
            if step < teacher.len() {
                let tgt = teacher[step] as usize;
                let lp = log_prob(logits, tgt);
                seq.logprobs.push(lp);
                if seq.tokens.len() <= pos {
                    seq.tokens.push(teacher[step]);
                }
                seq.generated += 1;
                emitted = Some((teacher[step], lp));
            }
            if seq.generated >= teacher.len().min(seq.max_new_tokens) {
                seq.done = true;
                seq.finish.get_or_insert(FinishReason::Length);
            }
        } else {
            let tok = seq.sampler.sample(logits);
            let lp = log_prob(logits, tok as usize);
            seq.logprobs.push(lp);
            seq.tokens.push(tok);
            seq.generated += 1;
            emitted = Some((tok, lp));
            if seq.stop_token == Some(tok) {
                seq.done = true;
                seq.finish.get_or_insert(FinishReason::Stop);
            } else if seq.generated >= seq.max_new_tokens
                || seq.tokens.len() >= self.cfg.max_seq_len
            {
                seq.done = true;
                seq.finish.get_or_insert(FinishReason::Length);
            }
        }
        if seq.tokens.len() >= self.cfg.max_seq_len {
            seq.done = true;
            seq.finish.get_or_insert(FinishReason::Length);
        }
        // Per-token stream delivery + serving latency histograms.
        if let Some((token, logprob)) = emitted {
            if let Some(j) = &self.journal {
                // `generated` was just bumped, so the 0-based stream
                // index of this token is generated - 1.
                j.step(seq.id, seq.generated - 1, token, logprob);
            }
            let now = Instant::now();
            if seq.generated == 1 {
                self.metrics
                    .observe_us("ttft", (now - seq.queued_at).as_secs_f64() * 1e6);
            } else if let Some(prev) = seq.last_token_at {
                self.metrics
                    .observe_us("inter_token", (now - prev).as_secs_f64() * 1e6);
            }
            seq.last_token_at = Some(now);
            if let Some(em) = &seq.emitter {
                em.send(SessionEvent::Token { token, logprob, index: seq.generated - 1 });
            }
        }
    }
}

impl StepStats {
    fn merge(&mut self, o: StepStats) {
        self.decoded += o.decoded;
        self.dispatches += o.dispatches;
    }
}
