//! Request and per-sequence serving state.

use crate::config::{PolicyKind, ServingConfig};
use crate::kvcache::SeqCache;
use crate::model::Sampler;
use crate::policy::{RadarPolicy, RadarVariant, SelectionPolicy};

pub type SeqId = u64;

/// An inbound generation request.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Teacher-forcing stream for PPL evaluation: if set, decode
    /// consumes these tokens instead of sampled ones and records
    /// per-token log-probs.
    pub teacher: Option<Vec<i32>>,
    /// Stop generation at this byte (e.g. b'\n'), if any.
    pub stop_token: Option<i32>,
}

impl GenRequest {
    pub fn new(prompt: Vec<i32>, max_new_tokens: usize) -> Self {
        Self { prompt, max_new_tokens, teacher: None, stop_token: None }
    }

    pub fn teacher_forced(prompt: Vec<i32>, teacher: Vec<i32>) -> Self {
        let n = teacher.len();
        Self { prompt, max_new_tokens: n, teacher: Some(teacher), stop_token: None }
    }
}

/// Completed generation.
#[derive(Debug, Clone)]
pub struct GenResult {
    pub id: SeqId,
    pub tokens: Vec<i32>,
    /// log p(token) for each generated/teacher-forced token.
    pub logprobs: Vec<f64>,
    pub prefill_ms: f64,
    pub decode_ms: f64,
}

impl GenResult {
    /// Perplexity over the recorded logprobs.
    pub fn ppl(&self) -> f64 {
        if self.logprobs.is_empty() {
            return f64::NAN;
        }
        let mean_nll: f64 =
            -self.logprobs.iter().sum::<f64>() / self.logprobs.len() as f64;
        mean_nll.exp()
    }
}

/// Which decode pipeline serves the sequence.
pub enum PolicyHolder {
    Fused(Box<dyn SelectionPolicy>),
    Radar(RadarPolicy),
}

pub struct Sequence {
    pub id: SeqId,
    pub cache: SeqCache,
    pub policy: PolicyHolder,
    pub sampler: Sampler,
    /// All tokens: prompt + generated (or teacher-forced).
    pub tokens: Vec<i32>,
    pub prompt_len: usize,
    pub teacher: Option<Vec<i32>>,
    pub stop_token: Option<i32>,
    pub max_new_tokens: usize,
    pub generated: usize,
    pub logprobs: Vec<f64>,
    pub done: bool,
    pub prefill_ms: f64,
    pub decode_ms: f64,
}

impl Sequence {
    pub fn new(id: SeqId, req: GenRequest, cfg: &ServingConfig, n_layers: usize, n_heads: usize) -> Self {
        let policy = match cfg.policy {
            PolicyKind::Radar => PolicyHolder::Radar(RadarPolicy::new(
                RadarVariant::Approx, n_layers, n_heads, cfg.n_feat, cfg.seed ^ id,
            )),
            PolicyKind::RadarExact => PolicyHolder::Radar(RadarPolicy::new(
                RadarVariant::Exact, n_layers, n_heads, cfg.n_feat, cfg.seed ^ id,
            )),
            PolicyKind::RadarRandom => PolicyHolder::Radar(RadarPolicy::new(
                RadarVariant::Random, n_layers, n_heads, cfg.n_feat, cfg.seed ^ id,
            )),
            PolicyKind::RadarLowest => PolicyHolder::Radar(RadarPolicy::new(
                RadarVariant::Lowest, n_layers, n_heads, cfg.n_feat, cfg.seed ^ id,
            )),
            _ => PolicyHolder::Fused(crate::policy::make_policy(cfg, n_layers * n_heads)),
        };
        Self {
            id,
            cache: SeqCache::new(cfg.n_feat),
            policy,
            sampler: Sampler::new(cfg.seed ^ (id << 1), cfg.temperature, cfg.greedy),
            tokens: req.prompt,
            prompt_len: 0, // set after prefill
            teacher: req.teacher,
            stop_token: req.stop_token,
            max_new_tokens: req.max_new_tokens,
            generated: 0,
            logprobs: Vec::new(),
            done: false,
            prefill_ms: 0.0,
            decode_ms: 0.0,
        }
    }

    /// The token this sequence feeds into the next decode step
    /// (position = cache.len()).
    pub fn next_input(&self) -> Option<i32> {
        let pos = self.cache.len();
        self.tokens.get(pos).copied()
    }

    pub fn result(&self) -> GenResult {
        GenResult {
            id: self.id,
            tokens: self.tokens.clone(),
            logprobs: self.logprobs.clone(),
            prefill_ms: self.prefill_ms,
            decode_ms: self.decode_ms,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ppl_of_uniform_logprobs() {
        let r = GenResult {
            id: 0,
            tokens: vec![],
            logprobs: vec![-(2.0f64.ln()); 10],
            prefill_ms: 0.0,
            decode_ms: 0.0,
        };
        assert!((r.ppl() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn teacher_request_sets_max_tokens() {
        let r = GenRequest::teacher_forced(vec![1, 2], vec![3, 4, 5]);
        assert_eq!(r.max_new_tokens, 3);
        assert!(r.teacher.is_some());
    }
}
