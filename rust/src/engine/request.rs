//! Request, session, and per-sequence serving state.
//!
//! The serving lifecycle is session-oriented: `Engine::submit` returns a
//! `SessionHandle` carrying a per-token event stream (`Token`, `Done`,
//! `Error`) plus a cancel flag the step loop checks every iteration.
//! The legacy blocking path (`Engine::add` + `run_to_completion` +
//! `GenResult`) remains for batch harnesses and tests.

use crate::config::{PolicyKind, ServingConfig};
use crate::engine::staging::StagedPlanes;
use crate::kvcache::SeqCache;
use crate::model::Sampler;
use crate::policy::{RadarPolicy, RadarVariant, Selection, SelectionPolicy};
use crate::util::threadpool::Channel;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

pub type SeqId = u64;

/// Admission priority class. Declaration order gives the derived `Ord`
/// (`Batch < Normal < High`): under load-shedding, lower classes are
/// dropped first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum Priority {
    /// Best-effort background work: first to be shed under pressure.
    Batch,
    /// Interactive traffic (the default).
    #[default]
    Normal,
    /// Latency-critical traffic: never shed for lower classes.
    High,
}

impl Priority {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "batch" => Some(Self::Batch),
            "normal" => Some(Self::Normal),
            "high" => Some(Self::High),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Self::Batch => "batch",
            Self::Normal => "normal",
            Self::High => "high",
        }
    }
}

/// An inbound generation request.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Teacher-forcing stream for PPL evaluation: if set, decode
    /// consumes these tokens instead of sampled ones and records
    /// per-token log-probs.
    pub teacher: Option<Vec<i32>>,
    /// Stop generation at this byte (e.g. b'\n'), if any.
    pub stop_token: Option<i32>,
    /// Per-request sampling overrides; `None` falls back to the
    /// engine's `ServingConfig`.
    pub temperature: Option<f32>,
    pub greedy: Option<bool>,
    pub seed: Option<u64>,
    /// Shared-prefix KV reuse for this request (API `cache: off` clears
    /// it). Both this and the engine-wide `ServingConfig::prefix_cache`
    /// must be on for the prompt to be seeded from the prefix index.
    pub prefix_cache: bool,
    /// Wall-clock deadline from submit to last token. `None` falls back
    /// to the engine's `ServingConfig::timeout_ms`; `Some(0)` opts out
    /// even when the engine has a default deadline.
    pub timeout_ms: Option<u64>,
    /// Admission priority class (load shedding drops lower classes
    /// first when the queue or KV pool crosses its watermark).
    pub priority: Priority,
}

/// Resolve a request's sampler parameters against the engine config:
/// `(seed, temperature, greedy)`. A request-supplied seed must be
/// reproducible verbatim across resubmissions, so it is NOT mixed with
/// the (monotonically increasing) session id; only the engine-wide
/// default is, to decorrelate concurrent sequences. `Sequence::new`
/// and the session journal's ADMIT record both use this, so a
/// recovered sequence rebuilds the exact sampler the crashed run had
/// even if `ServingConfig` changed across the restart.
pub fn resolved_sampling(id: SeqId, req: &GenRequest, cfg: &ServingConfig) -> (u64, f32, bool) {
    let seed = match req.seed {
        Some(s) => s,
        None => cfg.seed ^ (id << 1),
    };
    (seed, req.temperature.unwrap_or(cfg.temperature), req.greedy.unwrap_or(cfg.greedy))
}

impl GenRequest {
    pub fn new(prompt: Vec<i32>, max_new_tokens: usize) -> Self {
        Self {
            prompt,
            max_new_tokens,
            teacher: None,
            stop_token: None,
            temperature: None,
            greedy: None,
            seed: None,
            prefix_cache: true,
            timeout_ms: None,
            priority: Priority::default(),
        }
    }

    pub fn teacher_forced(prompt: Vec<i32>, teacher: Vec<i32>) -> Self {
        let n = teacher.len();
        let mut r = Self::new(prompt, n);
        r.teacher = Some(teacher);
        r
    }
}

/// Completed generation (legacy blocking API).
#[derive(Debug, Clone)]
pub struct GenResult {
    pub id: SeqId,
    pub tokens: Vec<i32>,
    /// log p(token) for each generated/teacher-forced token.
    pub logprobs: Vec<f64>,
    pub prefill_ms: f64,
    pub decode_ms: f64,
}

impl GenResult {
    /// Perplexity over the recorded logprobs.
    pub fn ppl(&self) -> f64 {
        if self.logprobs.is_empty() {
            return f64::NAN;
        }
        let mean_nll: f64 =
            -self.logprobs.iter().sum::<f64>() / self.logprobs.len() as f64;
        mean_nll.exp()
    }
}

// ---------------------------------------------------------------------
// Session API
// ---------------------------------------------------------------------

/// Why a session stopped producing tokens.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// Hit `max_new_tokens` (or the teacher stream / max_seq_len ran out).
    Length,
    /// Emitted the request's stop token.
    Stop,
    /// The client cancelled; KV blocks were freed immediately.
    Cancelled,
    /// The request's deadline (or the queue-wait deadline) expired
    /// before generation finished; already-produced tokens stand.
    Timeout,
}

impl FinishReason {
    pub fn as_str(&self) -> &'static str {
        match self {
            Self::Length => "length",
            Self::Stop => "stop",
            Self::Cancelled => "cancelled",
            Self::Timeout => "timeout",
        }
    }
}

/// Token accounting reported on `Done`.
#[derive(Debug, Clone, Default)]
pub struct Usage {
    pub prompt_tokens: usize,
    pub completion_tokens: usize,
    /// Prompt tokens served from the shared-prefix cache (not
    /// prefilled); <= prompt_tokens.
    pub cached_tokens: usize,
    pub prefill_ms: f64,
    pub decode_ms: f64,
}

impl Usage {
    pub fn total_tokens(&self) -> usize {
        self.prompt_tokens + self.completion_tokens
    }
}

/// One event on a session's stream.
#[derive(Debug, Clone)]
pub enum SessionEvent {
    /// One generated (or teacher-forced) token, emitted as soon as the
    /// engine step that produced it completes.
    Token { token: i32, logprob: f64, index: usize },
    /// Terminal: the sequence finished and its blocks were freed.
    Done { usage: Usage, finish: FinishReason },
    /// Terminal: the sequence failed; blocks were freed.
    Error(String),
}

/// Client half of a session: consume events, request cancellation.
///
/// The handle is cheap to clone and safe to move across threads; the
/// engine owns the producer side and closes the channel after the
/// terminal event, so `recv` drains remaining events then yields `None`.
#[derive(Clone)]
pub struct SessionHandle {
    pub id: SeqId,
    events: Channel<SessionEvent>,
    cancel: Arc<AtomicBool>,
}

/// Accumulated view of a session's stream (from `drain`/`collect`).
#[derive(Debug, Clone, Default)]
pub struct SessionResult {
    /// Generated tokens only (the prompt is not echoed).
    pub tokens: Vec<i32>,
    pub logprobs: Vec<f64>,
    pub usage: Option<Usage>,
    pub finish: Option<FinishReason>,
    pub error: Option<String>,
}

impl SessionHandle {
    pub(crate) fn new(id: SeqId, events: Channel<SessionEvent>, cancel: Arc<AtomicBool>) -> Self {
        Self { id, events, cancel }
    }

    /// Blocking receive; `None` once the stream is closed and drained.
    pub fn recv(&self) -> Option<SessionEvent> {
        self.events.recv()
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Option<SessionEvent> {
        self.events.try_recv()
    }

    /// Ask the engine to stop this sequence. The step loop observes the
    /// flag at the top of the next step and frees the KV blocks there.
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::Release);
    }

    pub fn is_cancelled(&self) -> bool {
        self.cancel.load(Ordering::Acquire)
    }

    /// Fold `events` into `out` until the stream would block.
    fn fold(&self, out: &mut SessionResult, blocking: bool) {
        loop {
            let ev = if blocking { self.events.recv() } else { self.events.try_recv() };
            let Some(ev) = ev else { break };
            match ev {
                SessionEvent::Token { token, logprob, .. } => {
                    out.tokens.push(token);
                    out.logprobs.push(logprob);
                }
                SessionEvent::Done { usage, finish } => {
                    out.usage = Some(usage);
                    out.finish = Some(finish);
                    break;
                }
                SessionEvent::Error(e) => {
                    out.error = Some(e);
                    break;
                }
            }
        }
    }

    /// Consume currently queued events without blocking.
    pub fn drain(&self) -> SessionResult {
        let mut out = SessionResult::default();
        self.fold(&mut out, false);
        out
    }

    /// Block until the terminal event (or channel close) and return the
    /// accumulated result. Only safe when another thread (or subsequent
    /// `Engine::step` calls on this thread) drives the engine.
    pub fn collect(&self) -> SessionResult {
        let mut out = SessionResult::default();
        self.fold(&mut out, true);
        out
    }
}

/// Admission failure surfaced by `Engine::submit` (maps to HTTP
/// 429/400/503; rate-limit and shed rejections carry a retry hint the
/// server turns into a `Retry-After` header).
#[derive(Debug, thiserror::Error)]
pub enum SubmitError {
    #[error("pending queue full ({depth} queued); retry later")]
    QueueFull { depth: usize },
    #[error("request needs {need} tokens > max_seq_len {max}")]
    TooLong { need: usize, max: usize },
    #[error("admission rate limited; retry in {retry_after_ms} ms")]
    RateLimited { retry_after_ms: u64 },
    #[error("shed under load: queue or KV pool over watermark; retry in {retry_after_ms} ms")]
    Shed { retry_after_ms: u64 },
    #[error("server draining; no new work accepted")]
    Draining,
}

/// Which decode pipeline serves the sequence.
pub enum PolicyHolder {
    Fused(Box<dyn SelectionPolicy>),
    Radar(RadarPolicy),
}

impl PolicyHolder {
    /// Build the configured policy for sequence `id`. Deterministic in
    /// (cfg, id): a preempted sequence rebuilds an identical policy and
    /// replays its prefill to the same state.
    pub fn fresh(id: SeqId, cfg: &ServingConfig, n_layers: usize, n_heads: usize) -> Self {
        let radar = |variant| {
            PolicyHolder::Radar(RadarPolicy::new(
                variant, n_layers, n_heads, cfg.n_feat, cfg.seed ^ id,
            ))
        };
        match cfg.policy {
            PolicyKind::Radar => radar(RadarVariant::Approx),
            PolicyKind::RadarExact => radar(RadarVariant::Exact),
            PolicyKind::RadarRandom => radar(RadarVariant::Random),
            PolicyKind::RadarLowest => radar(RadarVariant::Lowest),
            _ => PolicyHolder::Fused(crate::policy::make_policy(cfg, n_layers * n_heads)),
        }
    }
}

pub struct Sequence {
    pub id: SeqId,
    pub cache: SeqCache,
    pub policy: PolicyHolder,
    pub sampler: Sampler,
    /// All tokens: prompt + generated (or teacher-forced).
    pub tokens: Vec<i32>,
    pub prompt_len: usize,
    pub teacher: Option<Vec<i32>>,
    pub stop_token: Option<i32>,
    pub max_new_tokens: usize,
    pub generated: usize,
    /// Whether this request may use / populate the prefix cache.
    pub prefix_cache: bool,
    /// Prompt tokens seeded from the prefix cache instead of prefilled.
    pub cached_tokens: usize,
    pub logprobs: Vec<f64>,
    pub done: bool,
    pub finish: Option<FinishReason>,
    pub prefill_ms: f64,
    pub decode_ms: f64,
    /// Session plumbing: `None` for the legacy blocking path.
    pub emitter: Option<Channel<SessionEvent>>,
    pub cancel: Arc<AtomicBool>,
    /// Submit time (queue wait + prefill count toward TTFT).
    pub queued_at: Instant,
    pub last_token_at: Option<Instant>,
    /// Absolute wall-clock deadline; the per-step sweep finishes the
    /// sequence with `FinishReason::Timeout` once it passes.
    pub deadline: Option<Instant>,
    /// How many times KV pressure has preempted this sequence.
    pub preemptions: u32,
    /// Set while requeued after preemption (recovery-latency anchor).
    pub preempted_at: Option<Instant>,
    /// Incremental K/V staging arena: last step's gathered rows per
    /// (layer, head). Invalidated on preemption (the cache is freed).
    pub staging: StagedPlanes,
    /// Selection staged for the in-flight decode step (written by the
    /// batch planner, read by staging and post-dispatch policy hooks).
    pub cur_sel: Selection,
}

impl Sequence {
    pub fn new(
        id: SeqId,
        req: GenRequest,
        cfg: &ServingConfig,
        n_layers: usize,
        n_heads: usize,
    ) -> Self {
        let policy = PolicyHolder::fresh(id, cfg, n_layers, n_heads);
        let (sampler_seed, temperature, greedy) = resolved_sampling(id, &req, cfg);
        let prompt_len = req.prompt.len();
        Self {
            id,
            cache: SeqCache::new(cfg.n_feat),
            policy,
            sampler: Sampler::new(sampler_seed, temperature, greedy),
            tokens: req.prompt,
            prompt_len,
            teacher: req.teacher,
            stop_token: req.stop_token,
            max_new_tokens: req.max_new_tokens,
            generated: 0,
            prefix_cache: req.prefix_cache,
            cached_tokens: 0,
            logprobs: Vec::new(),
            done: false,
            finish: None,
            prefill_ms: 0.0,
            decode_ms: 0.0,
            emitter: None,
            cancel: Arc::new(AtomicBool::new(false)),
            queued_at: Instant::now(),
            last_token_at: None,
            deadline: None,
            preemptions: 0,
            preempted_at: None,
            staging: StagedPlanes::new(n_layers * n_heads),
            cur_sel: Selection::default(),
        }
    }

    /// The token this sequence feeds into the next decode step
    /// (position = cache.len()).
    pub fn next_input(&self) -> Option<i32> {
        let pos = self.cache.len();
        self.tokens.get(pos).copied()
    }

    pub fn is_cancelled(&self) -> bool {
        self.cancel.load(Ordering::Acquire)
    }

    pub fn usage(&self) -> Usage {
        Usage {
            prompt_tokens: self.prompt_len,
            completion_tokens: self.generated,
            cached_tokens: self.cached_tokens,
            prefill_ms: self.prefill_ms,
            decode_ms: self.decode_ms,
        }
    }

    pub fn result(&self) -> GenResult {
        GenResult {
            id: self.id,
            tokens: self.tokens.clone(),
            logprobs: self.logprobs.clone(),
            prefill_ms: self.prefill_ms,
            decode_ms: self.decode_ms,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ppl_of_uniform_logprobs() {
        let r = GenResult {
            id: 0,
            tokens: vec![],
            logprobs: vec![-(2.0f64.ln()); 10],
            prefill_ms: 0.0,
            decode_ms: 0.0,
        };
        assert!((r.ppl() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn teacher_request_sets_max_tokens() {
        let r = GenRequest::teacher_forced(vec![1, 2], vec![3, 4, 5]);
        assert_eq!(r.max_new_tokens, 3);
        assert!(r.teacher.is_some());
    }

    #[test]
    fn handle_drain_accumulates_tokens_then_done() {
        let ch: Channel<SessionEvent> = Channel::new();
        let cancel = Arc::new(AtomicBool::new(false));
        let h = SessionHandle::new(7, ch.clone(), cancel);
        ch.send(SessionEvent::Token { token: 65, logprob: -0.5, index: 0 });
        ch.send(SessionEvent::Token { token: 66, logprob: -0.25, index: 1 });
        ch.send(SessionEvent::Done {
            usage: Usage {
                prompt_tokens: 3,
                completion_tokens: 2,
                prefill_ms: 1.0,
                decode_ms: 2.0,
                ..Default::default()
            },
            finish: FinishReason::Length,
        });
        let out = h.drain();
        assert_eq!(out.tokens, vec![65, 66]);
        assert_eq!(out.logprobs, vec![-0.5, -0.25]);
        assert_eq!(out.finish, Some(FinishReason::Length));
        assert_eq!(out.usage.unwrap().total_tokens(), 5);
        assert!(out.error.is_none());
    }

    #[test]
    fn handle_collect_stops_on_error() {
        let ch: Channel<SessionEvent> = Channel::new();
        let h = SessionHandle::new(1, ch.clone(), Arc::new(AtomicBool::new(false)));
        ch.send(SessionEvent::Token { token: 1, logprob: -1.0, index: 0 });
        ch.send(SessionEvent::Error("boom".into()));
        ch.close();
        let out = h.collect();
        assert_eq!(out.tokens, vec![1]);
        assert_eq!(out.error.as_deref(), Some("boom"));
    }

    #[test]
    fn cancel_flag_is_shared() {
        let ch: Channel<SessionEvent> = Channel::new();
        let cancel = Arc::new(AtomicBool::new(false));
        let h = SessionHandle::new(1, ch, cancel.clone());
        assert!(!h.is_cancelled());
        h.cancel();
        assert!(cancel.load(Ordering::Acquire));
    }

    #[test]
    fn priority_orders_batch_below_normal_below_high() {
        assert!(Priority::Batch < Priority::Normal);
        assert!(Priority::Normal < Priority::High);
        assert_eq!(Priority::default(), Priority::Normal);
        for p in [Priority::Batch, Priority::Normal, Priority::High] {
            assert_eq!(Priority::parse(p.as_str()), Some(p));
        }
        assert_eq!(Priority::parse("urgent"), None);
        assert_eq!(GenRequest::new(vec![1], 4).priority, Priority::Normal);
    }

    #[test]
    fn finish_reason_strings() {
        assert_eq!(FinishReason::Length.as_str(), "length");
        assert_eq!(FinishReason::Stop.as_str(), "stop");
        assert_eq!(FinishReason::Cancelled.as_str(), "cancelled");
        assert_eq!(FinishReason::Timeout.as_str(), "timeout");
    }
}
