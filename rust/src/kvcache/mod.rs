//! Paged KV cache + φ-feature store (the vLLM-style substrate).
//!
//! A shared `BlockPool` owns fixed-size blocks; each block holds
//! `BLOCK_TOKENS` tokens of K, V and random features for **all**
//! (layer, head) planes. Blocks are **reference counted**: a block may
//! be owned by several sequences at once (shared-prompt prefix reuse,
//! see `crate::prefix`) and only returns to the free list when its last
//! owner releases it. Writes go through copy-on-write: appending into a
//! block another owner can still see first copies it.
//!
//! The hot-path `gather_*` routines copy policy-selected token rows
//! into the padded buffers the decode artifacts take as inputs.
//!
//! Layouts inside a block (row-major):
//!   k, v  : [L, H, BLOCK_TOKENS, dh]
//!   feat  : [L, H, BLOCK_TOKENS, n]

use crate::config::ModelConfig;
use anyhow::{anyhow, Result};

pub const BLOCK_TOKENS: usize = 16;

/// Typed out-of-blocks error. The engine downcasts step errors to this
/// to route KV pressure into preemption instead of failing the request.
#[derive(Debug, Clone, Copy, thiserror::Error)]
#[error("kv cache exhausted ({blocks} blocks = {tokens} tokens)")]
pub struct CacheExhausted {
    pub blocks: usize,
    pub tokens: usize,
}

struct Block {
    k: Vec<f32>,
    v: Vec<f32>,
    feat: Vec<f32>,
}

/// Shared allocator. Not thread-safe by itself — the engine serializes
/// access (single scheduler thread owns it).
pub struct BlockPool {
    cfg: ModelConfig,
    n_feat: usize,
    blocks: Vec<Block>,
    /// Per-block owner count; 0 == on the free list.
    refs: Vec<u32>,
    free: Vec<usize>,
    capacity: usize,
}

impl BlockPool {
    pub fn new(cfg: &ModelConfig, n_feat: usize, capacity_blocks: usize) -> Self {
        Self {
            cfg: cfg.clone(),
            n_feat,
            blocks: Vec::new(),
            refs: Vec::new(),
            free: Vec::new(),
            capacity: capacity_blocks,
        }
    }

    fn plane(&self) -> usize {
        self.cfg.n_layers * self.cfg.n_heads
    }

    fn kv_block_len(&self) -> usize {
        self.plane() * BLOCK_TOKENS * self.cfg.d_head
    }

    fn feat_block_len(&self) -> usize {
        self.plane() * BLOCK_TOKENS * self.n_feat
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    pub fn n_feat(&self) -> usize {
        self.n_feat
    }

    /// Bytes of K + V + feat storage one block occupies (the unit the
    /// prefix-cache eviction budget is denominated in).
    pub fn block_bytes(&self) -> usize {
        (2 * self.kv_block_len() + self.feat_block_len()) * std::mem::size_of::<f32>()
    }

    /// Allocate a block with an owner count of 1.
    pub fn allocate(&mut self) -> Result<usize> {
        if let Some(id) = self.free.pop() {
            debug_assert_eq!(self.refs[id], 0, "free-list block {id} still referenced");
            self.refs[id] = 1;
            return Ok(id);
        }
        if self.blocks.len() >= self.capacity {
            return Err(CacheExhausted {
                blocks: self.capacity,
                tokens: self.capacity * BLOCK_TOKENS,
            }
            .into());
        }
        let id = self.blocks.len();
        self.blocks.push(Block {
            k: vec![0.0; self.kv_block_len()],
            v: vec![0.0; self.kv_block_len()],
            feat: vec![0.0; self.feat_block_len()],
        });
        self.refs.push(1);
        Ok(id)
    }

    /// Add an owner to a live block (prefix sharing / seeded sequences).
    pub fn retain(&mut self, id: usize) {
        assert!(
            id < self.blocks.len() && self.refs[id] > 0,
            "retain of dead block {id}"
        );
        self.refs[id] += 1;
    }

    /// Current owner count (0 == on the free list).
    pub fn ref_count(&self, id: usize) -> u32 {
        if id < self.refs.len() {
            self.refs[id]
        } else {
            0
        }
    }

    /// Drop one owner from each block; a block returns to the free list
    /// only when its last owner releases it. Releasing a block that is
    /// already free (or was never allocated) is a hard error: it means
    /// two owners think they hold the same block exclusively, and
    /// continuing would alias live KV data.
    pub fn release(&mut self, ids: &[usize]) -> Result<()> {
        for &id in ids {
            if id >= self.blocks.len() || self.refs[id] == 0 {
                debug_assert!(false, "double release of block {id}");
                return Err(anyhow!("double release of kv block {id}"));
            }
            self.refs[id] -= 1;
            if self.refs[id] == 0 {
                self.free.push(id);
            }
        }
        Ok(())
    }

    /// Allocate a fresh block and copy `src`'s contents into it
    /// (the copy-on-write slow path).
    pub fn copy_block(&mut self, src: usize) -> Result<usize> {
        assert!(
            src < self.blocks.len() && self.refs[src] > 0,
            "copy of dead block {src}"
        );
        let dst = self.allocate()?;
        debug_assert_ne!(src, dst);
        let (a, b) = if src < dst { (src, dst) } else { (dst, src) };
        let (lo, hi) = self.blocks.split_at_mut(b);
        let (s, d) = if src < dst { (&lo[a], &mut hi[0]) } else { (&hi[0], &mut lo[a]) };
        d.k.copy_from_slice(&s.k);
        d.v.copy_from_slice(&s.v);
        d.feat.copy_from_slice(&s.feat);
        Ok(dst)
    }

    pub fn used_blocks(&self) -> usize {
        self.blocks.len() - self.free.len()
    }

    /// Hard block capacity (allocations past this fail with
    /// [`CacheExhausted`]).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn free_blocks(&self) -> usize {
        self.capacity - self.used_blocks()
    }
}

/// Per-sequence cache view: owns blocks in order; token i lives at
/// block `blocks[i / BT]`, slot `i % BT`.
pub struct SeqCache {
    pub blocks: Vec<usize>,
    len: usize,
    n_feat: usize,
}

impl SeqCache {
    pub fn new(n_feat: usize) -> Self {
        Self { blocks: Vec::new(), len: 0, n_feat }
    }

    /// Build a cache whose first `blocks.len() * BLOCK_TOKENS` tokens
    /// are the given (already-populated, full) shared blocks. Each block
    /// gains an owner; the prefix stays immutable because any write into
    /// a shared block goes through copy-on-write.
    pub fn seed_from_blocks(pool: &mut BlockPool, n_feat: usize, blocks: &[usize]) -> Self {
        for &b in blocks {
            pool.retain(b);
        }
        Self { blocks: blocks.to_vec(), len: blocks.len() * BLOCK_TOKENS, n_feat }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Copy-on-write guard: make the tail block exclusively ours before
    /// writing into it. Returns true when a copy was made.
    fn ensure_tail_writable(&mut self, pool: &mut BlockPool) -> Result<bool> {
        let bid = *self.blocks.last().expect("ensure_tail_writable on empty cache");
        if pool.ref_count(bid) <= 1 {
            return Ok(false);
        }
        let copy = pool.copy_block(bid)?;
        // Cannot hit zero: the other owner still holds a reference.
        pool.release(&[bid])?;
        *self.blocks.last_mut().unwrap() = copy;
        Ok(true)
    }

    /// Append one token's K/V/feat for every (l, h).
    /// Layouts: k_new/v_new [L, H, dh]; feat [L, H, n].
    pub fn append(
        &mut self,
        pool: &mut BlockPool,
        k_new: &[f32],
        v_new: &[f32],
        feat: &[f32],
    ) -> Result<()> {
        let cfg = &pool.cfg;
        let (lh, dh, nf) = (pool.plane(), cfg.d_head, pool.n_feat);
        debug_assert_eq!(k_new.len(), lh * dh);
        debug_assert_eq!(feat.len(), lh * nf);
        debug_assert_eq!(self.n_feat, nf);
        if self.len % BLOCK_TOKENS == 0 {
            let id = pool.allocate()?;
            self.blocks.push(id);
        } else {
            self.ensure_tail_writable(pool)?;
        }
        let slot = self.len % BLOCK_TOKENS;
        let bid = *self.blocks.last().unwrap();
        // Writes go plane by plane: src row (l,h) -> block offset.
        for p in 0..lh {
            let dst = (p * BLOCK_TOKENS + slot) * dh;
            let src = p * dh;
            pool.blocks[bid].k[dst..dst + dh].copy_from_slice(&k_new[src..src + dh]);
            pool.blocks[bid].v[dst..dst + dh].copy_from_slice(&v_new[src..src + dh]);
            let dstf = (p * BLOCK_TOKENS + slot) * nf;
            let srcf = p * nf;
            pool.blocks[bid].feat[dstf..dstf + nf]
                .copy_from_slice(&feat[srcf..srcf + nf]);
        }
        self.len += 1;
        Ok(())
    }

    /// Append the first `t_len` tokens of a prefill chunk whose source
    /// layout is [L, H, src_t, dh] / [L, H, src_t, n]. `t_len < src_t`
    /// when the chunk was padded (prompt tail); padded positions'
    /// outputs are simply not appended (causality makes the real
    /// positions' outputs independent of the padding).
    pub fn append_chunk(
        &mut self,
        pool: &mut BlockPool,
        t_len: usize,
        src_t: usize,
        k_c: &[f32],
        v_c: &[f32],
        feat_c: &[f32],
    ) -> Result<()> {
        let cfg = pool.cfg.clone();
        let (lh, dh, nf) = (pool.plane(), cfg.d_head, pool.n_feat);
        debug_assert!(t_len <= src_t);
        debug_assert_eq!(k_c.len(), lh * src_t * dh);
        for t in 0..t_len {
            if self.len % BLOCK_TOKENS == 0 {
                let id = pool.allocate()?;
                self.blocks.push(id);
            } else if t == 0 {
                // Only the first written token can land in a shared
                // tail block; blocks allocated inside this loop are ours.
                self.ensure_tail_writable(pool)?;
            }
            let slot = self.len % BLOCK_TOKENS;
            let bid = *self.blocks.last().unwrap();
            let blk = &mut pool.blocks[bid];
            for p in 0..lh {
                let src = (p * src_t + t) * dh;
                let dst = (p * BLOCK_TOKENS + slot) * dh;
                blk.k[dst..dst + dh].copy_from_slice(&k_c[src..src + dh]);
                blk.v[dst..dst + dh].copy_from_slice(&v_c[src..src + dh]);
                let srcf = (p * src_t + t) * nf;
                let dstf = (p * BLOCK_TOKENS + slot) * nf;
                blk.feat[dstf..dstf + nf].copy_from_slice(&feat_c[srcf..srcf + nf]);
            }
            self.len += 1;
        }
        Ok(())
    }

    #[inline]
    fn locate(&self, idx: usize) -> (usize, usize) {
        (self.blocks[idx / BLOCK_TOKENS], idx % BLOCK_TOKENS)
    }

    /// Read one token's key for plane (l, h) — O(1).
    pub fn key<'p>(&self, pool: &'p BlockPool, l: usize, h: usize, idx: usize) -> &'p [f32] {
        let (bid, slot) = self.locate(idx);
        let p = l * pool.cfg.n_heads + h;
        let dh = pool.cfg.d_head;
        let off = (p * BLOCK_TOKENS + slot) * dh;
        &pool.blocks[bid].k[off..off + dh]
    }

    pub fn feat<'p>(&self, pool: &'p BlockPool, l: usize, h: usize, idx: usize) -> &'p [f32] {
        let (bid, slot) = self.locate(idx);
        let p = l * pool.cfg.n_heads + h;
        let nf = pool.n_feat;
        let off = (p * BLOCK_TOKENS + slot) * nf;
        &pool.blocks[bid].feat[off..off + nf]
    }

    /// Gather selected tokens of plane (l, h) into `dst_k`/`dst_v`
    /// (each [S, dh], S >= sel.len(); rows beyond sel.len() untouched —
    /// callers zero or mask them).
    ///
    /// Consecutive selected indices that live in the same block are
    /// coalesced into a single `copy_from_slice` per K/V — selections
    /// are dominated by contiguous runs (sinks, segment spans, the
    /// sliding window), so the common case copies whole-run strides
    /// instead of one `dh` row at a time. Empty selections return
    /// without touching either dst slice.
    pub fn gather_plane(
        &self,
        pool: &BlockPool,
        l: usize,
        h: usize,
        sel: &[u32],
        dst_k: &mut [f32],
        dst_v: &mut [f32],
    ) {
        if sel.is_empty() {
            return;
        }
        let cfg = &pool.cfg;
        let dh = cfg.d_head;
        let p = l * cfg.n_heads + h;
        let base = p * BLOCK_TOKENS * dh;
        let mut row = 0;
        while row < sel.len() {
            let start = sel[row] as usize;
            let slot = start % BLOCK_TOKENS;
            // Longest run of consecutive token indices that stays
            // inside one block (runs never cross block boundaries).
            let max_run = (BLOCK_TOKENS - slot).min(sel.len() - row);
            let mut run = 1;
            while run < max_run && sel[row + run] as usize == start + run {
                run += 1;
            }
            let bid = self.blocks[start / BLOCK_TOKENS];
            let off = base + slot * dh;
            let n = run * dh;
            let dst = row * dh;
            let blk = &pool.blocks[bid];
            dst_k[dst..dst + n].copy_from_slice(&blk.k[off..off + n]);
            dst_v[dst..dst + n].copy_from_slice(&blk.v[off..off + n]);
            row += run;
        }
    }

    /// Gather contiguous [start, end) K/V for all planes into
    /// [L, H, P, dh] buffers (prefill past staging). P >= end-start.
    pub fn gather_past(
        &self,
        pool: &BlockPool,
        start: usize,
        end: usize,
        p_bucket: usize,
        dst_k: &mut [f32],
        dst_v: &mut [f32],
    ) {
        let cfg = &pool.cfg;
        let (dh, lh) = (cfg.d_head, pool.plane());
        debug_assert!(dst_k.len() >= lh * p_bucket * dh);
        for p in 0..lh {
            for (row, idx) in (start..end).enumerate() {
                let (bid, slot) = self.locate(idx);
                let off = (p * BLOCK_TOKENS + slot) * dh;
                let dst = (p * p_bucket + row) * dh;
                let blk = &pool.blocks[bid];
                dst_k[dst..dst + dh].copy_from_slice(&blk.k[off..off + dh]);
                dst_v[dst..dst + dh].copy_from_slice(&blk.v[off..off + dh]);
            }
        }
    }

    /// Drop this sequence's ownership of all its blocks; blocks shared
    /// with the prefix cache or other sequences stay alive.
    pub fn free(&mut self, pool: &mut BlockPool) -> Result<()> {
        let r = pool.release(&self.blocks);
        self.blocks.clear();
        self.len = 0;
        r
    }

    /// How many of this sequence's blocks have other owners too.
    pub fn shared_blocks(&self, pool: &BlockPool) -> usize {
        self.blocks.iter().filter(|&&b| pool.ref_count(b) > 1).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::minitest::check;
    use crate::util::prng::SplitMix64;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            d_head: 4,
            d_ffn: 16,
            n_feat: 8,
            max_train_len: 64,
            vocab: 16,
        }
    }

    fn fill_token(seed: usize, lh: usize, dh: usize, nf: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let k: Vec<f32> = (0..lh * dh).map(|i| (seed * 1000 + i) as f32).collect();
        let v: Vec<f32> = k.iter().map(|x| x + 0.5).collect();
        let f: Vec<f32> = (0..lh * nf).map(|i| (seed * 7 + i) as f32).collect();
        (k, v, f)
    }

    #[test]
    fn append_then_read_back() {
        let c = cfg();
        let mut pool = BlockPool::new(&c, 8, 100);
        let mut seq = SeqCache::new(8);
        for t in 0..40 {
            let (k, v, f) = fill_token(t, 4, 4, 8);
            seq.append(&mut pool, &k, &v, &f).unwrap();
        }
        assert_eq!(seq.len(), 40);
        assert_eq!(seq.blocks.len(), 3); // ceil(40/16)
        // token 17, plane (l=1,h=0) => p=2, src offset 2*4=8
        let got = seq.key(&pool, 1, 0, 17);
        let (want_k, _, _) = fill_token(17, 4, 4, 8);
        assert_eq!(got, &want_k[8..12]);
    }

    #[test]
    fn gather_matches_pointwise_reads() {
        let c = cfg();
        let mut pool = BlockPool::new(&c, 8, 100);
        let mut seq = SeqCache::new(8);
        for t in 0..50 {
            let (k, v, f) = fill_token(t, 4, 4, 8);
            seq.append(&mut pool, &k, &v, &f).unwrap();
        }
        let sel = [3u32, 17, 31, 49];
        let mut dk = vec![0.0; 8 * 4];
        let mut dv = vec![0.0; 8 * 4];
        seq.gather_plane(&pool, 1, 1, &sel, &mut dk, &mut dv);
        for (row, &idx) in sel.iter().enumerate() {
            assert_eq!(&dk[row * 4..row * 4 + 4], seq.key(&pool, 1, 1, idx as usize));
        }
    }

    #[test]
    fn gather_coalesced_runs_match_pointwise_reads() {
        // Contiguous runs spanning block boundaries (15,16,17 crosses
        // blocks 0->1) must coalesce correctly and still equal
        // pointwise reads, K and V both.
        let c = cfg();
        let mut pool = BlockPool::new(&c, 8, 100);
        let mut seq = SeqCache::new(8);
        for t in 0..50 {
            let (k, v, f) = fill_token(t, 4, 4, 8);
            seq.append(&mut pool, &k, &v, &f).unwrap();
        }
        let sel: Vec<u32> = vec![0, 1, 2, 3, 15, 16, 17, 30, 31, 32, 33, 34, 48, 49];
        let mut dk = vec![-1.0; (sel.len() + 2) * 4];
        let mut dv = vec![-1.0; (sel.len() + 2) * 4];
        seq.gather_plane(&pool, 1, 0, &sel, &mut dk, &mut dv);
        for (row, &idx) in sel.iter().enumerate() {
            assert_eq!(
                &dk[row * 4..row * 4 + 4],
                seq.key(&pool, 1, 0, idx as usize),
                "K row {row} (token {idx})"
            );
            let (_, want_v, _) = fill_token(idx as usize, 4, 4, 8);
            assert_eq!(&dv[row * 4..row * 4 + 4], &want_v[8..12], "V row {row}");
        }
        // Rows past sel.len() stay untouched.
        assert!(dk[sel.len() * 4..].iter().all(|&x| x == -1.0));
        assert!(dv[sel.len() * 4..].iter().all(|&x| x == -1.0));
    }

    #[test]
    fn gather_empty_selection_leaves_dst_untouched() {
        let c = cfg();
        let mut pool = BlockPool::new(&c, 8, 100);
        let mut seq = SeqCache::new(8);
        let (k, v, f) = fill_token(0, 4, 4, 8);
        seq.append(&mut pool, &k, &v, &f).unwrap();
        let mut dk = vec![7.0; 4 * 4];
        let mut dv = vec![9.0; 4 * 4];
        seq.gather_plane(&pool, 0, 0, &[], &mut dk, &mut dv);
        assert!(dk.iter().all(|&x| x == 7.0), "empty sel must not write K");
        assert!(dv.iter().all(|&x| x == 9.0), "empty sel must not write V");
    }

    #[test]
    fn append_chunk_equals_append_tokens() {
        let c = cfg();
        let (lh, dh, nf, t_len) = (4, 4, 8, 20);
        let mut pool1 = BlockPool::new(&c, 8, 100);
        let mut pool2 = BlockPool::new(&c, 8, 100);
        let mut s1 = SeqCache::new(8);
        let mut s2 = SeqCache::new(8);
        // chunk layout [L,H,T,dh]
        let mut kc = vec![0.0; lh * t_len * dh];
        let mut vc = vec![0.0; lh * t_len * dh];
        let mut fc = vec![0.0; lh * t_len * nf];
        for t in 0..t_len {
            let (k, v, f) = fill_token(t, lh, dh, nf);
            for p in 0..lh {
                for j in 0..dh {
                    kc[(p * t_len + t) * dh + j] = k[p * dh + j];
                    vc[(p * t_len + t) * dh + j] = v[p * dh + j];
                }
                for j in 0..nf {
                    fc[(p * t_len + t) * nf + j] = f[p * nf + j];
                }
            }
            s1.append(&mut pool1, &k, &v, &f).unwrap();
        }
        s2.append_chunk(&mut pool2, t_len, t_len, &kc, &vc, &fc).unwrap();
        assert_eq!(s1.len(), s2.len());
        for idx in 0..t_len {
            for l in 0..2 {
                for h in 0..2 {
                    assert_eq!(
                        s1.key(&pool1, l, h, idx),
                        s2.key(&pool2, l, h, idx),
                        "mismatch at token {idx} plane ({l},{h})"
                    );
                }
            }
        }
    }

    #[test]
    fn append_chunk_partial_with_stride() {
        // Padded tail: append only the first 5 tokens of a 16-wide chunk.
        let c = cfg();
        let (lh, dh, nf, src_t, real) = (4, 4, 8, 16, 5);
        let mut pool = BlockPool::new(&c, 8, 100);
        let mut seq = SeqCache::new(8);
        let mut kc = vec![0.0f32; lh * src_t * dh];
        let vc = kc.clone();
        let fc = vec![0.0f32; lh * src_t * nf];
        for p in 0..lh {
            for t in 0..src_t {
                for j in 0..dh {
                    kc[(p * src_t + t) * dh + j] = (p * 1000 + t * 10 + j) as f32;
                }
            }
        }
        seq.append_chunk(&mut pool, real, src_t, &kc, &vc, &fc).unwrap();
        assert_eq!(seq.len(), real);
        // token 3, plane (1,0)=p2 must equal source row (2, 3).
        let got = seq.key(&pool, 1, 0, 3);
        let want: Vec<f32> = (0..4).map(|j| (2 * 1000 + 3 * 10 + j) as f32).collect();
        assert_eq!(got, &want[..]);
    }

    #[test]
    fn free_then_reuse() {
        let c = cfg();
        let mut pool = BlockPool::new(&c, 8, 4); // 64 tokens capacity
        let mut seq = SeqCache::new(8);
        let (k, v, f) = fill_token(0, 4, 4, 8);
        for _ in 0..64 {
            seq.append(&mut pool, &k, &v, &f).unwrap();
        }
        assert!(seq.append(&mut pool, &k, &v, &f).is_err(), "capacity enforced");
        seq.free(&mut pool).unwrap();
        assert_eq!(pool.used_blocks(), 0);
        let mut seq2 = SeqCache::new(8);
        for _ in 0..64 {
            seq2.append(&mut pool, &k, &v, &f).unwrap();
        }
    }

    #[test]
    fn prop_allocator_never_aliases_live_blocks() {
        // Property: interleaved alloc/free across many sequences never
        // hands the same block to two live sequences.
        check(
            42,
            50,
            |r: &mut SplitMix64| {
                (0..30).map(|_| r.below(3) as usize).collect::<Vec<usize>>()
            },
            |ops| {
                let c = cfg();
                let mut pool = BlockPool::new(&c, 8, 64);
                let mut seqs: Vec<SeqCache> = Vec::new();
                let (k, v, f) = fill_token(0, 4, 4, 8);
                for &op in ops {
                    match op {
                        0 => seqs.push(SeqCache::new(8)),
                        1 => {
                            if let Some(s) = seqs.iter_mut().last() {
                                let _ = s.append(&mut pool, &k, &v, &f);
                            }
                        }
                        _ => {
                            if !seqs.is_empty() {
                                let mut s = seqs.remove(0);
                                s.free(&mut pool).unwrap();
                            }
                        }
                    }
                    let mut live: Vec<usize> =
                        seqs.iter().flat_map(|s| s.blocks.iter().copied()).collect();
                    let n = live.len();
                    live.sort_unstable();
                    live.dedup();
                    if live.len() != n {
                        return Err("block aliased across live sequences".into());
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn double_release_is_hard_error() {
        let c = cfg();
        let mut pool = BlockPool::new(&c, 8, 8);
        let id = pool.allocate().unwrap();
        pool.release(&[id]).unwrap();
        // Releasing a block already on the free list must fail loudly,
        // not silently corrupt the free list.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.release(&[id])
        }));
        if cfg!(debug_assertions) {
            assert!(result.is_err(), "debug builds must assert on double release");
        } else {
            assert!(result.unwrap().is_err(), "release builds must return Err");
        }
        // Never-allocated ids are equally fatal.
        let mut pool2 = BlockPool::new(&c, 8, 8);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool2.release(&[3])
        }));
        if cfg!(debug_assertions) {
            assert!(result.is_err());
        } else {
            assert!(result.unwrap().is_err());
        }
    }

    #[test]
    fn refcounted_block_survives_one_owner_release() {
        let c = cfg();
        let mut pool = BlockPool::new(&c, 8, 8);
        let id = pool.allocate().unwrap();
        pool.retain(id);
        assert_eq!(pool.ref_count(id), 2);
        pool.release(&[id]).unwrap();
        assert_eq!(pool.ref_count(id), 1);
        assert_eq!(pool.used_blocks(), 1, "still owned by the other holder");
        pool.release(&[id]).unwrap();
        assert_eq!(pool.ref_count(id), 0);
        assert_eq!(pool.used_blocks(), 0);
    }

    #[test]
    fn append_chunk_exactly_fills_block() {
        // A chunk of exactly BLOCK_TOKENS tokens must fill one block and
        // leave the next append allocating a fresh one.
        let c = cfg();
        let (lh, dh, nf) = (4, 4, 8);
        let mut pool = BlockPool::new(&c, 8, 100);
        let mut seq = SeqCache::new(8);
        let t_len = BLOCK_TOKENS;
        let kc = vec![1.0f32; lh * t_len * dh];
        let vc = kc.clone();
        let fc = vec![2.0f32; lh * t_len * nf];
        seq.append_chunk(&mut pool, t_len, t_len, &kc, &vc, &fc).unwrap();
        assert_eq!(seq.len(), BLOCK_TOKENS);
        assert_eq!(seq.blocks.len(), 1);
        let (k, v, f) = fill_token(0, lh, dh, nf);
        seq.append(&mut pool, &k, &v, &f).unwrap();
        assert_eq!(seq.blocks.len(), 2, "next token starts a new block");
        assert_eq!(seq.key(&pool, 0, 0, BLOCK_TOKENS), &k[..4]);
    }

    #[test]
    fn append_chunk_spanning_many_blocks_matches_tokenwise() {
        // One chunk covering 3+ blocks (and a ragged tail) must equal
        // token-by-token appends.
        let c = cfg();
        let (lh, dh, nf) = (4, 4, 8);
        let t_len = 3 * BLOCK_TOKENS + 5; // 53 tokens -> 4 blocks
        let mut pool1 = BlockPool::new(&c, 8, 100);
        let mut pool2 = BlockPool::new(&c, 8, 100);
        let mut s1 = SeqCache::new(8);
        let mut s2 = SeqCache::new(8);
        let mut kc = vec![0.0; lh * t_len * dh];
        let mut vc = vec![0.0; lh * t_len * dh];
        let mut fc = vec![0.0; lh * t_len * nf];
        for t in 0..t_len {
            let (k, v, f) = fill_token(t, lh, dh, nf);
            for p in 0..lh {
                for j in 0..dh {
                    kc[(p * t_len + t) * dh + j] = k[p * dh + j];
                    vc[(p * t_len + t) * dh + j] = v[p * dh + j];
                }
                for j in 0..nf {
                    fc[(p * t_len + t) * nf + j] = f[p * nf + j];
                }
            }
            s1.append(&mut pool1, &k, &v, &f).unwrap();
        }
        s2.append_chunk(&mut pool2, t_len, t_len, &kc, &vc, &fc).unwrap();
        assert_eq!(s2.len(), t_len);
        assert_eq!(s2.blocks.len(), 4);
        for idx in [0, 15, 16, 31, 32, 47, 48, 52] {
            for l in 0..2 {
                for h in 0..2 {
                    assert_eq!(
                        s1.key(&pool1, l, h, idx),
                        s2.key(&pool2, l, h, idx),
                        "token {idx} plane ({l},{h})"
                    );
                }
            }
        }
    }

    #[test]
    fn append_chunk_empty_is_noop() {
        let c = cfg();
        let mut pool = BlockPool::new(&c, 8, 100);
        let mut seq = SeqCache::new(8);
        // Empty chunk on an empty cache: no allocation, no length change.
        seq.append_chunk(&mut pool, 0, 16, &vec![0.0; 4 * 16 * 4], &vec![0.0; 4 * 16 * 4], &vec![0.0; 4 * 16 * 8]).unwrap();
        assert_eq!(seq.len(), 0);
        assert!(seq.blocks.is_empty());
        assert_eq!(pool.used_blocks(), 0);
        // And on a partially-filled cache: state untouched.
        let (k, v, f) = fill_token(1, 4, 4, 8);
        seq.append(&mut pool, &k, &v, &f).unwrap();
        seq.append_chunk(&mut pool, 0, 16, &vec![0.0; 4 * 16 * 4], &vec![0.0; 4 * 16 * 4], &vec![0.0; 4 * 16 * 8]).unwrap();
        assert_eq!(seq.len(), 1);
        assert_eq!(seq.blocks.len(), 1);
        assert_eq!(seq.key(&pool, 0, 0, 0), &k[..4]);
    }

    #[test]
    fn cow_append_into_shared_tail_copies() {
        let c = cfg();
        let mut pool = BlockPool::new(&c, 8, 100);
        let mut seq = SeqCache::new(8);
        for t in 0..20 {
            let (k, v, f) = fill_token(t, 4, 4, 8);
            seq.append(&mut pool, &k, &v, &f).unwrap();
        }
        // Simulate a second owner of the (partial) tail block.
        let tail = *seq.blocks.last().unwrap();
        pool.retain(tail);
        let snapshot: Vec<f32> = seq.key(&pool, 0, 0, 17).to_vec();
        let (k, v, f) = fill_token(99, 4, 4, 8);
        seq.append(&mut pool, &k, &v, &f).unwrap();
        let new_tail = *seq.blocks.last().unwrap();
        assert_ne!(new_tail, tail, "shared tail must be copied before write");
        assert_eq!(pool.ref_count(tail), 1, "our ownership moved to the copy");
        // Existing tokens are visible through the copy...
        assert_eq!(seq.key(&pool, 0, 0, 17), &snapshot[..]);
        // ...and the new token landed in the copy, not the shared block.
        assert_eq!(seq.key(&pool, 0, 0, 20), &k[..4]);
    }

    #[test]
    fn cow_append_chunk_into_shared_tail_copies() {
        let c = cfg();
        let (lh, dh, nf) = (4, 4, 8);
        let mut pool = BlockPool::new(&c, 8, 100);
        let mut seq = SeqCache::new(8);
        for t in 0..10 {
            let (k, v, f) = fill_token(t, lh, dh, nf);
            seq.append(&mut pool, &k, &v, &f).unwrap();
        }
        let tail = *seq.blocks.last().unwrap();
        pool.retain(tail);
        let t_len = 20; // spans the shared tail + a fresh block
        let kc = vec![3.0f32; lh * t_len * dh];
        let vc = kc.clone();
        let fc = vec![4.0f32; lh * t_len * nf];
        seq.append_chunk(&mut pool, t_len, t_len, &kc, &vc, &fc).unwrap();
        assert_ne!(seq.blocks[0], tail);
        assert_eq!(pool.ref_count(tail), 1);
        let (k0, _, _) = fill_token(0, lh, dh, nf);
        assert_eq!(seq.key(&pool, 0, 0, 0), &k0[..4], "pre-COW tokens preserved");
        assert_eq!(seq.key(&pool, 0, 0, 10), &[3.0; 4][..], "chunk written to copy");
    }

    #[test]
    fn seed_from_blocks_shares_and_reads_identically() {
        let c = cfg();
        let mut pool = BlockPool::new(&c, 8, 100);
        let mut donor = SeqCache::new(8);
        for t in 0..32 {
            let (k, v, f) = fill_token(t, 4, 4, 8);
            donor.append(&mut pool, &k, &v, &f).unwrap();
        }
        let used_before = pool.used_blocks();
        let seeded = SeqCache::seed_from_blocks(&mut pool, 8, &donor.blocks);
        assert_eq!(seeded.len(), 32);
        assert_eq!(pool.used_blocks(), used_before, "seeding allocates nothing");
        assert_eq!(seeded.shared_blocks(&pool), 2);
        for idx in [0, 15, 16, 31] {
            assert_eq!(seeded.key(&pool, 1, 1, idx), donor.key(&pool, 1, 1, idx));
        }
        // Donor exits; the seeded sequence keeps the blocks alive.
        let blocks = donor.blocks.clone();
        donor.free(&mut pool).unwrap();
        assert!(blocks.iter().all(|&b| pool.ref_count(b) == 1));
        assert_eq!(seeded.key(&pool, 0, 0, 5).len(), 4);
    }

    #[test]
    fn gather_past_layout() {
        let c = cfg();
        let mut pool = BlockPool::new(&c, 8, 100);
        let mut seq = SeqCache::new(8);
        for t in 0..30 {
            let (k, v, f) = fill_token(t, 4, 4, 8);
            seq.append(&mut pool, &k, &v, &f).unwrap();
        }
        let p_bucket = 32;
        let mut dk = vec![0.0; 4 * p_bucket * 4];
        let mut dv = vec![0.0; 4 * p_bucket * 4];
        seq.gather_past(&pool, 5, 25, p_bucket, &mut dk, &mut dv);
        // plane (0,1)=p1, row 0 == token 5
        let off = (1 * p_bucket + 0) * 4;
        assert_eq!(&dk[off..off + 4], seq.key(&pool, 0, 1, 5));
        // plane (1,1)=p3, row 19 == token 24
        let off = (3 * p_bucket + 19) * 4;
        assert_eq!(&dk[off..off + 4], seq.key(&pool, 1, 1, 24));
    }
}
