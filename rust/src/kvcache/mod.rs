//! Paged KV cache + φ-feature store (the vLLM-style substrate).
//!
//! A shared `BlockPool` owns fixed-size blocks; each block holds
//! `BLOCK_TOKENS` tokens of K, V and random features for **all**
//! (layer, head) planes. Sequences own a list of block ids; freeing a
//! sequence returns its blocks to the pool. The hot-path `gather_*`
//! routines copy policy-selected token rows into the padded buffers
//! the decode artifacts take as inputs.
//!
//! Layouts inside a block (row-major):
//!   k, v  : [L, H, BLOCK_TOKENS, dh]
//!   feat  : [L, H, BLOCK_TOKENS, n]

use crate::config::ModelConfig;
use anyhow::{anyhow, Result};

pub const BLOCK_TOKENS: usize = 16;

struct Block {
    k: Vec<f32>,
    v: Vec<f32>,
    feat: Vec<f32>,
}

/// Shared allocator. Not thread-safe by itself — the engine serializes
/// access (single scheduler thread owns it).
pub struct BlockPool {
    cfg: ModelConfig,
    n_feat: usize,
    blocks: Vec<Block>,
    free: Vec<usize>,
    capacity: usize,
}

impl BlockPool {
    pub fn new(cfg: &ModelConfig, n_feat: usize, capacity_blocks: usize) -> Self {
        Self {
            cfg: cfg.clone(),
            n_feat,
            blocks: Vec::new(),
            free: Vec::new(),
            capacity: capacity_blocks,
        }
    }

    fn plane(&self) -> usize {
        self.cfg.n_layers * self.cfg.n_heads
    }

    fn kv_block_len(&self) -> usize {
        self.plane() * BLOCK_TOKENS * self.cfg.d_head
    }

    fn feat_block_len(&self) -> usize {
        self.plane() * BLOCK_TOKENS * self.n_feat
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    pub fn n_feat(&self) -> usize {
        self.n_feat
    }

    pub fn allocate(&mut self) -> Result<usize> {
        if let Some(id) = self.free.pop() {
            return Ok(id);
        }
        if self.blocks.len() >= self.capacity {
            return Err(anyhow!(
                "kv cache exhausted ({} blocks = {} tokens)",
                self.capacity,
                self.capacity * BLOCK_TOKENS
            ));
        }
        let id = self.blocks.len();
        self.blocks.push(Block {
            k: vec![0.0; self.kv_block_len()],
            v: vec![0.0; self.kv_block_len()],
            feat: vec![0.0; self.feat_block_len()],
        });
        Ok(id)
    }

    pub fn release(&mut self, ids: &[usize]) {
        for &id in ids {
            debug_assert!(!self.free.contains(&id), "double free of block {id}");
            self.free.push(id);
        }
    }

    pub fn used_blocks(&self) -> usize {
        self.blocks.len() - self.free.len()
    }

    pub fn free_blocks(&self) -> usize {
        self.capacity - self.used_blocks()
    }
}

/// Per-sequence cache view: owns blocks in order; token i lives at
/// block `blocks[i / BT]`, slot `i % BT`.
pub struct SeqCache {
    pub blocks: Vec<usize>,
    len: usize,
    n_feat: usize,
}

impl SeqCache {
    pub fn new(n_feat: usize) -> Self {
        Self { blocks: Vec::new(), len: 0, n_feat }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Append one token's K/V/feat for every (l, h).
    /// Layouts: k_new/v_new [L, H, dh]; feat [L, H, n].
    pub fn append(
        &mut self,
        pool: &mut BlockPool,
        k_new: &[f32],
        v_new: &[f32],
        feat: &[f32],
    ) -> Result<()> {
        let cfg = &pool.cfg;
        let (lh, dh, nf) = (pool.plane(), cfg.d_head, pool.n_feat);
        debug_assert_eq!(k_new.len(), lh * dh);
        debug_assert_eq!(feat.len(), lh * nf);
        debug_assert_eq!(self.n_feat, nf);
        if self.len % BLOCK_TOKENS == 0 {
            let id = pool.allocate()?;
            self.blocks.push(id);
        }
        let slot = self.len % BLOCK_TOKENS;
        let bid = *self.blocks.last().unwrap();
        // Writes go plane by plane: src row (l,h) -> block offset.
        for p in 0..lh {
            let dst = (p * BLOCK_TOKENS + slot) * dh;
            let src = p * dh;
            pool.blocks[bid].k[dst..dst + dh].copy_from_slice(&k_new[src..src + dh]);
            pool.blocks[bid].v[dst..dst + dh].copy_from_slice(&v_new[src..src + dh]);
            let dstf = (p * BLOCK_TOKENS + slot) * nf;
            let srcf = p * nf;
            pool.blocks[bid].feat[dstf..dstf + nf]
                .copy_from_slice(&feat[srcf..srcf + nf]);
        }
        self.len += 1;
        Ok(())
    }

    /// Append the first `t_len` tokens of a prefill chunk whose source
    /// layout is [L, H, src_t, dh] / [L, H, src_t, n]. `t_len < src_t`
    /// when the chunk was padded (prompt tail); padded positions'
    /// outputs are simply not appended (causality makes the real
    /// positions' outputs independent of the padding).
    pub fn append_chunk(
        &mut self,
        pool: &mut BlockPool,
        t_len: usize,
        src_t: usize,
        k_c: &[f32],
        v_c: &[f32],
        feat_c: &[f32],
    ) -> Result<()> {
        let cfg = pool.cfg.clone();
        let (lh, dh, nf) = (pool.plane(), cfg.d_head, pool.n_feat);
        debug_assert!(t_len <= src_t);
        debug_assert_eq!(k_c.len(), lh * src_t * dh);
        for t in 0..t_len {
            if self.len % BLOCK_TOKENS == 0 {
                let id = pool.allocate()?;
                self.blocks.push(id);
            }
            let slot = self.len % BLOCK_TOKENS;
            let bid = *self.blocks.last().unwrap();
            let blk = &mut pool.blocks[bid];
            for p in 0..lh {
                let src = (p * src_t + t) * dh;
                let dst = (p * BLOCK_TOKENS + slot) * dh;
                blk.k[dst..dst + dh].copy_from_slice(&k_c[src..src + dh]);
                blk.v[dst..dst + dh].copy_from_slice(&v_c[src..src + dh]);
                let srcf = (p * src_t + t) * nf;
                let dstf = (p * BLOCK_TOKENS + slot) * nf;
                blk.feat[dstf..dstf + nf].copy_from_slice(&feat_c[srcf..srcf + nf]);
            }
            self.len += 1;
        }
        Ok(())
    }

    #[inline]
    fn locate(&self, idx: usize) -> (usize, usize) {
        (self.blocks[idx / BLOCK_TOKENS], idx % BLOCK_TOKENS)
    }

    /// Read one token's key for plane (l, h) — O(1).
    pub fn key<'p>(&self, pool: &'p BlockPool, l: usize, h: usize, idx: usize) -> &'p [f32] {
        let (bid, slot) = self.locate(idx);
        let p = l * pool.cfg.n_heads + h;
        let dh = pool.cfg.d_head;
        let off = (p * BLOCK_TOKENS + slot) * dh;
        &pool.blocks[bid].k[off..off + dh]
    }

    pub fn feat<'p>(&self, pool: &'p BlockPool, l: usize, h: usize, idx: usize) -> &'p [f32] {
        let (bid, slot) = self.locate(idx);
        let p = l * pool.cfg.n_heads + h;
        let nf = pool.n_feat;
        let off = (p * BLOCK_TOKENS + slot) * nf;
        &pool.blocks[bid].feat[off..off + nf]
    }

    /// Gather selected tokens of plane (l, h) into `dst_k`/`dst_v`
    /// (each [S, dh], S >= sel.len(); rows beyond sel.len() untouched —
    /// callers zero or mask them).
    pub fn gather_plane(
        &self,
        pool: &BlockPool,
        l: usize,
        h: usize,
        sel: &[u32],
        dst_k: &mut [f32],
        dst_v: &mut [f32],
    ) {
        let cfg = &pool.cfg;
        let dh = cfg.d_head;
        let p = l * cfg.n_heads + h;
        let base = p * BLOCK_TOKENS * dh;
        for (row, &idx) in sel.iter().enumerate() {
            let (bid, slot) = self.locate(idx as usize);
            let off = base + slot * dh;
            let blk = &pool.blocks[bid];
            dst_k[row * dh..(row + 1) * dh].copy_from_slice(&blk.k[off..off + dh]);
            dst_v[row * dh..(row + 1) * dh].copy_from_slice(&blk.v[off..off + dh]);
        }
    }

    /// Gather contiguous [start, end) K/V for all planes into
    /// [L, H, P, dh] buffers (prefill past staging). P >= end-start.
    pub fn gather_past(
        &self,
        pool: &BlockPool,
        start: usize,
        end: usize,
        p_bucket: usize,
        dst_k: &mut [f32],
        dst_v: &mut [f32],
    ) {
        let cfg = &pool.cfg;
        let (dh, lh) = (cfg.d_head, pool.plane());
        debug_assert!(dst_k.len() >= lh * p_bucket * dh);
        for p in 0..lh {
            for (row, idx) in (start..end).enumerate() {
                let (bid, slot) = self.locate(idx);
                let off = (p * BLOCK_TOKENS + slot) * dh;
                let dst = (p * p_bucket + row) * dh;
                let blk = &pool.blocks[bid];
                dst_k[dst..dst + dh].copy_from_slice(&blk.k[off..off + dh]);
                dst_v[dst..dst + dh].copy_from_slice(&blk.v[off..off + dh]);
            }
        }
    }

    /// Release all blocks back to the pool.
    pub fn free(&mut self, pool: &mut BlockPool) {
        pool.release(&self.blocks);
        self.blocks.clear();
        self.len = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::minitest::check;
    use crate::util::prng::SplitMix64;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            d_head: 4,
            d_ffn: 16,
            n_feat: 8,
            max_train_len: 64,
            vocab: 16,
        }
    }

    fn fill_token(seed: usize, lh: usize, dh: usize, nf: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let k: Vec<f32> = (0..lh * dh).map(|i| (seed * 1000 + i) as f32).collect();
        let v: Vec<f32> = k.iter().map(|x| x + 0.5).collect();
        let f: Vec<f32> = (0..lh * nf).map(|i| (seed * 7 + i) as f32).collect();
        (k, v, f)
    }

    #[test]
    fn append_then_read_back() {
        let c = cfg();
        let mut pool = BlockPool::new(&c, 8, 100);
        let mut seq = SeqCache::new(8);
        for t in 0..40 {
            let (k, v, f) = fill_token(t, 4, 4, 8);
            seq.append(&mut pool, &k, &v, &f).unwrap();
        }
        assert_eq!(seq.len(), 40);
        assert_eq!(seq.blocks.len(), 3); // ceil(40/16)
        // token 17, plane (l=1,h=0) => p=2, src offset 2*4=8
        let got = seq.key(&pool, 1, 0, 17);
        let (want_k, _, _) = fill_token(17, 4, 4, 8);
        assert_eq!(got, &want_k[8..12]);
    }

    #[test]
    fn gather_matches_pointwise_reads() {
        let c = cfg();
        let mut pool = BlockPool::new(&c, 8, 100);
        let mut seq = SeqCache::new(8);
        for t in 0..50 {
            let (k, v, f) = fill_token(t, 4, 4, 8);
            seq.append(&mut pool, &k, &v, &f).unwrap();
        }
        let sel = [3u32, 17, 31, 49];
        let mut dk = vec![0.0; 8 * 4];
        let mut dv = vec![0.0; 8 * 4];
        seq.gather_plane(&pool, 1, 1, &sel, &mut dk, &mut dv);
        for (row, &idx) in sel.iter().enumerate() {
            assert_eq!(&dk[row * 4..row * 4 + 4], seq.key(&pool, 1, 1, idx as usize));
        }
    }

    #[test]
    fn append_chunk_equals_append_tokens() {
        let c = cfg();
        let (lh, dh, nf, t_len) = (4, 4, 8, 20);
        let mut pool1 = BlockPool::new(&c, 8, 100);
        let mut pool2 = BlockPool::new(&c, 8, 100);
        let mut s1 = SeqCache::new(8);
        let mut s2 = SeqCache::new(8);
        // chunk layout [L,H,T,dh]
        let mut kc = vec![0.0; lh * t_len * dh];
        let mut vc = vec![0.0; lh * t_len * dh];
        let mut fc = vec![0.0; lh * t_len * nf];
        for t in 0..t_len {
            let (k, v, f) = fill_token(t, lh, dh, nf);
            for p in 0..lh {
                for j in 0..dh {
                    kc[(p * t_len + t) * dh + j] = k[p * dh + j];
                    vc[(p * t_len + t) * dh + j] = v[p * dh + j];
                }
                for j in 0..nf {
                    fc[(p * t_len + t) * nf + j] = f[p * nf + j];
                }
            }
            s1.append(&mut pool1, &k, &v, &f).unwrap();
        }
        s2.append_chunk(&mut pool2, t_len, t_len, &kc, &vc, &fc).unwrap();
        assert_eq!(s1.len(), s2.len());
        for idx in 0..t_len {
            for l in 0..2 {
                for h in 0..2 {
                    assert_eq!(
                        s1.key(&pool1, l, h, idx),
                        s2.key(&pool2, l, h, idx),
                        "mismatch at token {idx} plane ({l},{h})"
                    );
                }
            }
        }
    }

    #[test]
    fn append_chunk_partial_with_stride() {
        // Padded tail: append only the first 5 tokens of a 16-wide chunk.
        let c = cfg();
        let (lh, dh, nf, src_t, real) = (4, 4, 8, 16, 5);
        let mut pool = BlockPool::new(&c, 8, 100);
        let mut seq = SeqCache::new(8);
        let mut kc = vec![0.0f32; lh * src_t * dh];
        let vc = kc.clone();
        let fc = vec![0.0f32; lh * src_t * nf];
        for p in 0..lh {
            for t in 0..src_t {
                for j in 0..dh {
                    kc[(p * src_t + t) * dh + j] = (p * 1000 + t * 10 + j) as f32;
                }
            }
        }
        seq.append_chunk(&mut pool, real, src_t, &kc, &vc, &fc).unwrap();
        assert_eq!(seq.len(), real);
        // token 3, plane (1,0)=p2 must equal source row (2, 3).
        let got = seq.key(&pool, 1, 0, 3);
        let want: Vec<f32> = (0..4).map(|j| (2 * 1000 + 3 * 10 + j) as f32).collect();
        assert_eq!(got, &want[..]);
    }

    #[test]
    fn free_then_reuse() {
        let c = cfg();
        let mut pool = BlockPool::new(&c, 8, 4); // 64 tokens capacity
        let mut seq = SeqCache::new(8);
        let (k, v, f) = fill_token(0, 4, 4, 8);
        for _ in 0..64 {
            seq.append(&mut pool, &k, &v, &f).unwrap();
        }
        assert!(seq.append(&mut pool, &k, &v, &f).is_err(), "capacity enforced");
        seq.free(&mut pool);
        assert_eq!(pool.used_blocks(), 0);
        let mut seq2 = SeqCache::new(8);
        for _ in 0..64 {
            seq2.append(&mut pool, &k, &v, &f).unwrap();
        }
    }

    #[test]
    fn prop_allocator_never_aliases_live_blocks() {
        // Property: interleaved alloc/free across many sequences never
        // hands the same block to two live sequences.
        check(
            42,
            50,
            |r: &mut SplitMix64| {
                (0..30).map(|_| r.below(3) as usize).collect::<Vec<usize>>()
            },
            |ops| {
                let c = cfg();
                let mut pool = BlockPool::new(&c, 8, 64);
                let mut seqs: Vec<SeqCache> = Vec::new();
                let (k, v, f) = fill_token(0, 4, 4, 8);
                for &op in ops {
                    match op {
                        0 => seqs.push(SeqCache::new(8)),
                        1 => {
                            if let Some(s) = seqs.iter_mut().last() {
                                let _ = s.append(&mut pool, &k, &v, &f);
                            }
                        }
                        _ => {
                            if !seqs.is_empty() {
                                let mut s = seqs.remove(0);
                                s.free(&mut pool);
                            }
                        }
                    }
                    let mut live: Vec<usize> =
                        seqs.iter().flat_map(|s| s.blocks.iter().copied()).collect();
                    let n = live.len();
                    live.sort_unstable();
                    live.dedup();
                    if live.len() != n {
                        return Err("block aliased across live sequences".into());
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn gather_past_layout() {
        let c = cfg();
        let mut pool = BlockPool::new(&c, 8, 100);
        let mut seq = SeqCache::new(8);
        for t in 0..30 {
            let (k, v, f) = fill_token(t, 4, 4, 8);
            seq.append(&mut pool, &k, &v, &f).unwrap();
        }
        let p_bucket = 32;
        let mut dk = vec![0.0; 4 * p_bucket * 4];
        let mut dv = vec![0.0; 4 * p_bucket * 4];
        seq.gather_past(&pool, 5, 25, p_bucket, &mut dk, &mut dv);
        // plane (0,1)=p1, row 0 == token 5
        let off = (1 * p_bucket + 0) * 4;
        assert_eq!(&dk[off..off + 4], seq.key(&pool, 0, 1, 5));
        // plane (1,1)=p3, row 19 == token 24
        let off = (3 * p_bucket + 19) * 4;
        assert_eq!(&dk[off..off + 4], seq.key(&pool, 1, 1, 24));
    }
}
