//! The Radar policy (query-dependent, per-layer pipeline) and its
//! Fig. 5 ablation variants.
//!
//! Per decode step, per layer l, the engine hands us phi(q) (and the
//! raw q for the exact ablation) for every head; we score the segments
//! (Eq. 6), pick top-k (or random / lowest / exact per the variant),
//! and return the token set: sinks ∪ top-segment tokens ∪ window W.

use super::Selection;
use crate::config::ServingConfig;
use crate::kvcache::{BlockPool, SeqCache};
use crate::radar::{exact_segment_scores, top_k_indices, FrozenSegments, RadarIndex};
use crate::util::prng::SplitMix64;
use crate::util::threadpool::ThreadPool;
use std::sync::Arc;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RadarVariant {
    /// The paper: approximate top-k via random features.
    Approx,
    /// Exact segment attention mass (O(t) per step — upper bound).
    Exact,
    /// Uniformly random k segments ("uneducated guess").
    Random,
    /// Bottom-k approximate scores (anti-oracle).
    Lowest,
}

pub struct RadarPolicy {
    pub variant: RadarVariant,
    pub index: RadarIndex,
    /// Frozen segment means for a shared prompt prefix (set when the
    /// sequence was seeded from the prefix cache); restructures adopt
    /// matching segments instead of recomputing them.
    pub donor: Option<Arc<FrozenSegments>>,
    /// Engine-wide degraded mode: when set, `select_layer` skips the
    /// approximation entirely and returns exact (full-context)
    /// attention for every plane.
    pub force_full: bool,
    /// Planes whose phi(q)/scores tripped the NaN/Inf/denormal detector
    /// in the most recent `select_layer` call (those planes fell back
    /// to full-context attention). Reset on every call.
    pub anomalous_planes: u32,
    lh: usize,
    n_heads: usize,
    rng: SplitMix64,
    scratch: Vec<f32>,
    /// Per-head score scratch for the pooled scoring path (one arena
    /// per head so workers never share a buffer).
    head_scratch: Vec<Vec<f32>>,
}

/// NaN/Inf/denormal detection: any such value means the random-feature
/// approximation (or the scores built from it) can no longer rank
/// segments meaningfully.
fn anomalous(xs: &[f32]) -> bool {
    xs.iter().any(|&x| !x.is_finite() || x.is_subnormal())
}

/// One head's selection: sinks ∪ top-k segment tokens ∪ window. Free
/// function (no `&mut self`) so the pooled path can run heads on
/// worker threads, each with its own `scratch`. Returns the selection
/// and whether the plane tripped the anomaly detector (caller counts
/// those; anomalous planes fall back to full context).
#[allow(clippy::too_many_arguments)]
fn plane_select(
    variant: RadarVariant,
    index: &RadarIndex,
    seq: &SeqCache,
    pool: &BlockPool,
    cfg: &ServingConfig,
    l: usize,
    h: usize,
    n_heads: usize,
    phi_q: &[f32],
    q_raw: &[f32],
    boundary: usize,
    random_segs: Option<Vec<usize>>,
    scratch: &mut Vec<f32>,
) -> (Vec<u32>, bool) {
    let t = seq.len();
    let n_feat = pool.n_feat();
    let dh = pool.config().d_head;
    let (c, n_segs) = (index.c, index.n_segs);
    let p = l * n_heads + h;
    let mut sel: Vec<u32> = Vec::new();
    // Sinks (clipped to boundary; window covers the rest).
    let sink_end = cfg.sinks.min(boundary).min(t);
    sel.extend(0..sink_end as u32);
    // Top-k segments.
    if n_segs > 0 && c > 0 {
        let k = cfg.radar_k.min(n_segs);
        // The detector must run *before* top_k_indices, whose
        // bit-pattern ordering assumes NaN-free scores.
        let mut anomaly = false;
        let chosen: Vec<usize> = match variant {
            RadarVariant::Approx => {
                let qf = &phi_q[h * n_feat..(h + 1) * n_feat];
                index.scores(p, qf, scratch);
                anomaly = anomalous(qf) || anomalous(scratch);
                if anomaly { Vec::new() } else { top_k_indices(scratch, k) }
            }
            RadarVariant::Exact => {
                let q = &q_raw[h * dh..(h + 1) * dh];
                exact_segment_scores(seq, pool, l, h, q, c, n_segs, scratch);
                anomaly = anomalous(scratch);
                if anomaly { Vec::new() } else { top_k_indices(scratch, k) }
            }
            RadarVariant::Random => random_segs.unwrap_or_default(),
            RadarVariant::Lowest => {
                let qf = &phi_q[h * n_feat..(h + 1) * n_feat];
                index.scores(p, qf, scratch);
                anomaly = anomalous(qf) || anomalous(scratch);
                if anomaly {
                    Vec::new()
                } else {
                    let neg: Vec<f32> = scratch.iter().map(|s| -s).collect();
                    top_k_indices(&neg, k)
                }
            }
        };
        if anomaly {
            return ((0..t as u32).collect(), true);
        }
        let mut segs = chosen;
        segs.sort_unstable();
        for s in segs {
            let start = (s * c).max(sink_end) as u32;
            sel.extend(start..((s + 1) * c) as u32);
        }
    }
    // Window W = [boundary, t).
    sel.extend(boundary as u32..t as u32);
    sel.sort_unstable();
    sel.dedup();
    (sel, false)
}

impl RadarPolicy {
    pub fn new(variant: RadarVariant, n_layers: usize, n_heads: usize, n_feat: usize, seed: u64) -> Self {
        Self {
            variant,
            index: RadarIndex::new(n_layers * n_heads, n_feat),
            donor: None,
            force_full: false,
            anomalous_planes: 0,
            lh: n_layers * n_heads,
            n_heads,
            rng: SplitMix64::new(seed ^ 0xDA7A),
            scratch: Vec::new(),
            head_scratch: Vec::new(),
        }
    }

    /// Call after the cache grows to `t` tokens (prefill chunks call it
    /// per token boundary crossing; decode per token). Alg. 1 line 8.
    pub fn on_grow(&mut self, pool: &BlockPool, seq: &SeqCache) -> bool {
        self.index
            .maybe_restructure_with(seq, pool, seq.len(), self.donor.as_deref())
    }

    /// Post-prefill initialization, adopting any frozen donor segments.
    pub fn force_restructure(&mut self, seq: &SeqCache, pool: &BlockPool) {
        self.index
            .force_restructure_with(seq, pool, self.donor.as_deref())
    }

    /// Selection for layer l. `phi_q` is [H, n] (head-major), `q_raw`
    /// [H, dh] (for the exact variant). Returns per-head index lists.
    ///
    /// Degradation paths: with `force_full` set (engine circuit breaker
    /// open) every plane attends the full context; otherwise a plane
    /// whose phi(q) or segment scores contain NaN/Inf/denormals falls
    /// back to full context for this step and is counted in
    /// `anomalous_planes` — the approximation never silently corrupts a
    /// generation.
    pub fn select_layer(
        &mut self,
        pool: &BlockPool,
        seq: &SeqCache,
        cfg: &ServingConfig,
        l: usize,
        phi_q: &[f32],
        q_raw: &[f32],
    ) -> Vec<Vec<u32>> {
        self.select_layer_with(None, pool, seq, cfg, l, phi_q, q_raw)
    }

    /// Like [`select_layer`](Self::select_layer), but with `Some(pool)`
    /// the per-head scoring (the phi-feature dot products + top-k) is
    /// sharded across the thread pool, one job per head with a private
    /// scratch arena. Bit-identical to the serial path: every head runs
    /// the same arithmetic on the same inputs, only on another thread;
    /// the Random variant's rng draws stay on the caller thread in head
    /// order, so its draw sequence is unchanged too.
    #[allow(clippy::too_many_arguments)]
    pub fn select_layer_with(
        &mut self,
        threads: Option<&ThreadPool>,
        pool: &BlockPool,
        seq: &SeqCache,
        cfg: &ServingConfig,
        l: usize,
        phi_q: &[f32],
        q_raw: &[f32],
    ) -> Vec<Vec<u32>> {
        let t = seq.len();
        self.anomalous_planes = 0;
        if self.force_full {
            return (0..self.n_heads).map(|_| (0..t as u32).collect()).collect();
        }
        // The attended window = the unregistered buffer W (Alg. 1)
        // extended to at least cfg.window recent tokens (the paper runs
        // every method with the same sliding window; Radar's retrieved
        // segments come on top of it).
        let boundary = self.index.boundary.min(t.saturating_sub(cfg.window));
        // Random draws are sequential by construction (one rng): take
        // them up front in head order so the pooled path consumes the
        // stream exactly like the serial one.
        let random_segs: Option<Vec<Vec<usize>>> = (self.variant == RadarVariant::Random
            && self.index.n_segs > 0
            && self.index.c > 0)
            .then(|| {
                let k = cfg.radar_k.min(self.index.n_segs);
                let n_segs = self.index.n_segs;
                (0..self.n_heads).map(|_| self.rng.sample_indices(n_segs, k)).collect()
            });
        let variant = self.variant;
        let n_heads = self.n_heads;
        match threads {
            Some(tp) if n_heads > 1 => {
                self.head_scratch.resize_with(n_heads, Vec::new);
                let mut results: Vec<(Vec<u32>, bool)> = vec![(Vec::new(), false); n_heads];
                let index = &self.index;
                let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = results
                    .iter_mut()
                    .zip(self.head_scratch.iter_mut())
                    .enumerate()
                    .map(|(h, (slot, scratch))| {
                        let rand_h = random_segs.as_ref().map(|r| r[h].clone());
                        Box::new(move || {
                            *slot = plane_select(
                                variant, index, seq, pool, cfg, l, h, n_heads, phi_q, q_raw,
                                boundary, rand_h, scratch,
                            );
                        }) as Box<dyn FnOnce() + Send + '_>
                    })
                    .collect();
                tp.scoped(jobs);
                let mut out = Vec::with_capacity(n_heads);
                for (sel, anomaly) in results {
                    if anomaly {
                        self.anomalous_planes += 1;
                    }
                    out.push(sel);
                }
                out
            }
            _ => {
                let mut out = Vec::with_capacity(n_heads);
                let mut scratch = std::mem::take(&mut self.scratch);
                for h in 0..n_heads {
                    let rand_h = random_segs.as_ref().map(|r| r[h].clone());
                    let (sel, anomaly) = plane_select(
                        variant,
                        &self.index,
                        seq,
                        pool,
                        cfg,
                        l,
                        h,
                        n_heads,
                        phi_q,
                        q_raw,
                        boundary,
                        rand_h,
                        &mut scratch,
                    );
                    if anomaly {
                        self.anomalous_planes += 1;
                    }
                    out.push(sel);
                }
                self.scratch = scratch;
                out
            }
        }
    }

    /// Upper bound on per-plane selection length at context t (used to
    /// pick the attn_mlp bucket before running selection).
    pub fn max_sel_len(&self, cfg: &ServingConfig, t: usize) -> usize {
        let seg_tokens = cfg.radar_k.min(self.index.n_segs) * self.index.c;
        cfg.sinks + seg_tokens + (t - self.index.boundary).max(cfg.window)
    }

    /// Full-step selection across all layers (used by the Fig. 7
    /// harness which has explicit per-layer queries).
    pub fn select_all_layers(
        &mut self,
        pool: &BlockPool,
        seq: &SeqCache,
        cfg: &ServingConfig,
        phi_q_all: &[f32], // [L, H, n]
        q_all: &[f32],     // [L, H, dh]
    ) -> Selection {
        let n_feat = pool.n_feat();
        let dh = pool.config().d_head;
        let n_layers = self.lh / self.n_heads;
        let mut per_plane = Vec::with_capacity(self.lh);
        for l in 0..n_layers {
            let pq = &phi_q_all[l * self.n_heads * n_feat..(l + 1) * self.n_heads * n_feat];
            let qr = &q_all[l * self.n_heads * dh..(l + 1) * self.n_heads * dh];
            per_plane.extend(self.select_layer(pool, seq, cfg, l, pq, qr));
        }
        Selection { per_plane }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    fn mcfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            d_head: 4,
            d_ffn: 16,
            n_feat: 8,
            max_train_len: 64,
            vocab: 16,
        }
    }

    /// Builds a cache whose segment s has features ~ one-hot(s % 8),
    /// so a one-hot phi(q) retrieves a known segment.
    fn build(t: usize) -> (BlockPool, SeqCache) {
        let c = mcfg();
        let mut pool = BlockPool::new(&c, 8, 1000);
        let mut seq = SeqCache::new(8);
        for tok in 0..t {
            let k: Vec<f32> = (0..16).map(|i| ((tok + i) % 5) as f32 * 0.1).collect();
            let seg_of_8 = (tok / 8) % 8; // aligned with c=8 at t=64
            let mut f = vec![0.0f32; 4 * 8];
            for p in 0..4 {
                f[p * 8 + seg_of_8] = 1.0;
            }
            seq.append(&mut pool, &k, &k.clone(), &f).unwrap();
        }
        (pool, seq)
    }

    fn scfg() -> ServingConfig {
        let mut s = ServingConfig::default();
        s.sinks = 2;
        s.radar_k = 2;
        s.n_feat = 8;
        s.window = 0; // tests exercise the pure Alg.-1 W buffer
        s
    }

    #[test]
    fn retrieves_the_matching_segment() {
        let (pool, seq) = build(64);
        let mut p = RadarPolicy::new(RadarVariant::Approx, 2, 2, 8, 0);
        assert!(p.on_grow(&pool, &seq));
        assert_eq!(p.index.c, 8);
        // phi(q) = one-hot(3) -> segment 3 (tokens 24..32) must be picked.
        let mut phi_q = vec![0.0f32; 2 * 8];
        phi_q[3] = 1.0; // head 0
        phi_q[8 + 3] = 1.0; // head 1
        let q_raw = vec![0.0f32; 2 * 4];
        let sel = p.select_layer(&pool, &seq, &scfg(), 0, &phi_q, &q_raw);
        assert!(sel[0].contains(&24) && sel[0].contains(&31));
    }

    #[test]
    fn selection_includes_sinks_and_window() {
        let (pool, seq) = build(70); // boundary 64 after restructure at 64
        let mut p = RadarPolicy::new(RadarVariant::Approx, 2, 2, 8, 0);
        for t in 1..=70 {
            if t * t <= 70 {} // no-op; restructures happen via on_grow below
        }
        // Simulate growth: restructure happens at t=64.
        p.index.maybe_restructure(&seq, &pool, 64);
        let phi_q = vec![0.1f32; 16];
        let q_raw = vec![0.0f32; 8];
        let sel = p.select_layer(&pool, &seq, &scfg(), 1, &phi_q, &q_raw);
        for plane in &sel {
            assert!(plane.contains(&0) && plane.contains(&1), "sinks");
            for w in 64..70u32 {
                assert!(plane.contains(&w), "window token {w}");
            }
            let mut sorted = plane.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(&sorted, plane, "sorted + unique");
        }
    }

    #[test]
    fn before_first_restructure_everything_is_window() {
        let (pool, seq) = build(3);
        let mut p = RadarPolicy::new(RadarVariant::Approx, 2, 2, 8, 0);
        // t=3: only t=1 restructure may have fired; boundary stays small.
        let phi_q = vec![0.1f32; 16];
        let sel = p.select_layer(&pool, &seq, &scfg(), 0, &phi_q, &[0.0; 8]);
        assert_eq!(sel[0], vec![0, 1, 2]);
    }

    #[test]
    fn variants_differ_and_respect_k() {
        let (pool, seq) = build(64);
        let cfg = scfg();
        let phi_q = vec![0.3f32; 16];
        let q_raw = vec![0.2f32; 8];
        let mut lens = Vec::new();
        for v in [RadarVariant::Approx, RadarVariant::Exact, RadarVariant::Random, RadarVariant::Lowest] {
            let mut p = RadarPolicy::new(v, 2, 2, 8, 1);
            p.on_grow(&pool, &seq);
            let sel = p.select_layer(&pool, &seq, &cfg, 0, &phi_q, &q_raw);
            // <= sinks + k*c + window(0 here, boundary=64=t)
            assert!(sel[0].len() <= 2 + 2 * 8, "variant {v:?}: {}", sel[0].len());
            lens.push(sel[0].clone());
        }
        // Approx and Lowest must differ on a non-degenerate index
        // (top-2 vs bottom-2 of the same scores) unless all scores tie.
    }

    #[test]
    fn pooled_selection_matches_serial_for_every_variant() {
        let (pool, seq) = build(64);
        let cfg = scfg();
        let tp = ThreadPool::new(3, "score");
        let phi_q: Vec<f32> = (0..16).map(|i| (i % 7) as f32 * 0.13).collect();
        let q_raw: Vec<f32> = (0..8).map(|i| (i % 3) as f32 * 0.21).collect();
        for v in [
            RadarVariant::Approx,
            RadarVariant::Exact,
            RadarVariant::Random,
            RadarVariant::Lowest,
        ] {
            let mut serial = RadarPolicy::new(v, 2, 2, 8, 5);
            let mut pooled = RadarPolicy::new(v, 2, 2, 8, 5);
            serial.on_grow(&pool, &seq);
            pooled.on_grow(&pool, &seq);
            for l in 0..2 {
                let a = serial.select_layer(&pool, &seq, &cfg, l, &phi_q, &q_raw);
                let b = pooled.select_layer_with(Some(&tp), &pool, &seq, &cfg, l, &phi_q, &q_raw);
                assert_eq!(a, b, "variant {v:?} layer {l} diverged under pooling");
                assert_eq!(serial.anomalous_planes, pooled.anomalous_planes);
            }
        }
    }

    #[test]
    fn pooled_anomaly_fallback_matches_serial() {
        let (pool, seq) = build(64);
        let cfg = scfg();
        let tp = ThreadPool::new(2, "score");
        let mut serial = RadarPolicy::new(RadarVariant::Approx, 2, 2, 8, 0);
        let mut pooled = RadarPolicy::new(RadarVariant::Approx, 2, 2, 8, 0);
        serial.on_grow(&pool, &seq);
        pooled.on_grow(&pool, &seq);
        // NaN phi(q) on head 1 only: that plane must fall back to full
        // context on both paths, head 0 unaffected.
        let mut phi_q = vec![0.1f32; 16];
        phi_q[8] = f32::NAN;
        let q_raw = vec![0.0f32; 8];
        let a = serial.select_layer(&pool, &seq, &cfg, 0, &phi_q, &q_raw);
        let b = pooled.select_layer_with(Some(&tp), &pool, &seq, &cfg, 0, &phi_q, &q_raw);
        assert_eq!(a, b);
        assert_eq!(serial.anomalous_planes, 1);
        assert_eq!(pooled.anomalous_planes, 1);
        assert_eq!(b[1], (0..64).collect::<Vec<u32>>(), "anomalous plane is full-context");
    }

    #[test]
    fn max_sel_len_bounds_actual() {
        let (pool, seq) = build(70);
        let cfg = scfg();
        let mut p = RadarPolicy::new(RadarVariant::Approx, 2, 2, 8, 0);
        p.index.maybe_restructure(&seq, &pool, 64);
        let bound = p.max_sel_len(&cfg, 70);
        let phi_q = vec![0.3f32; 16];
        let sel = p.select_layer(&pool, &seq, &cfg, 0, &phi_q, &[0.0; 8]);
        for plane in &sel {
            assert!(plane.len() <= bound, "{} > {}", plane.len(), bound);
        }
    }
}
