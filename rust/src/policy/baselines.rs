//! Query-independent baseline policies: vanilla, StreamingLLM, H2O,
//! SnapKV, SubGen. All run on the fused one-dispatch decode path.

use super::{sinks_and_window, SelectCtx, Selection, SelectionPolicy};
use crate::config::PolicyKind;
use crate::util::prng::SplitMix64;

// ---------------------------------------------------------------------------
// Vanilla: attend to everything (the quadratic baseline).
// ---------------------------------------------------------------------------

pub struct VanillaPolicy {
    lh: usize,
}

impl VanillaPolicy {
    pub fn new(lh: usize) -> Self {
        Self { lh }
    }
}

impl SelectionPolicy for VanillaPolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::Vanilla
    }

    fn select(&mut self, ctx: &SelectCtx) -> Selection {
        Selection::uniform(self.lh, (0..ctx.t as u32).collect())
    }

    fn prefix_reuse_safe(&self) -> bool {
        true // stateless: selection depends only on t
    }
}

// ---------------------------------------------------------------------------
// StreamingLLM (Xiao et al. 2024): sinks + sliding window. Middle
// tokens are *permanently* invisible — the information-loss failure
// mode the paper's Fig. 2 shows.
// ---------------------------------------------------------------------------

pub struct StreamingPolicy {
    lh: usize,
}

impl StreamingPolicy {
    pub fn new(lh: usize) -> Self {
        Self { lh }
    }
}

impl SelectionPolicy for StreamingPolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::Streaming
    }

    fn select(&mut self, ctx: &SelectCtx) -> Selection {
        let c = ctx.cfg;
        // budget = window + middle allowance n_c (paper: 32 + n_c); for
        // streaming the whole budget extends the window.
        let span = c.window + c.budget;
        let w_start = ctx.t.saturating_sub(span);
        Selection::uniform(self.lh, sinks_and_window(c.sinks, w_start, ctx.t))
    }

    fn prefix_reuse_safe(&self) -> bool {
        true // stateless: selection depends only on t
    }
}

// ---------------------------------------------------------------------------
// H2O (Zhang et al. 2023): keep sinks + window + the `budget` heaviest
// hitters by *accumulated* attention mass; evicted tokens never return.
// Accumulators update from the probs/colsum feedback.
// ---------------------------------------------------------------------------

pub struct H2OPolicy {
    lh: usize,
    /// Accumulated attention mass per plane per retained token.
    /// acc[p] maps token idx -> score; evicted tokens are removed and
    /// can never re-enter (the paper's criticism).
    acc: Vec<std::collections::HashMap<u32, f32>>,
    evicted: Vec<std::collections::HashSet<u32>>,
}

impl H2OPolicy {
    pub fn new(lh: usize) -> Self {
        Self {
            lh,
            acc: vec![Default::default(); lh],
            evicted: vec![Default::default(); lh],
        }
    }

    fn evict_overflow(&mut self, p: usize, keep: usize) {
        let over = self.acc[p].len().saturating_sub(keep);
        if over == 0 {
            return;
        }
        let mut entries: Vec<(u32, f32)> =
            self.acc[p].iter().map(|(&i, &s)| (i, s)).collect();
        entries.sort_by(|a, b| a.1.total_cmp(&b.1));
        for (idx, _) in entries.into_iter().take(over) {
            self.acc[p].remove(&idx);
            self.evicted[p].insert(idx);
        }
    }
}

impl SelectionPolicy for H2OPolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::H2O
    }

    fn select(&mut self, ctx: &SelectCtx) -> Selection {
        let c = ctx.cfg;
        let w_start = ctx.t.saturating_sub(c.window);
        let base = sinks_and_window(c.sinks, w_start, ctx.t);
        let mut per_plane = Vec::with_capacity(self.lh);
        for p in 0..self.lh {
            let mut sel = base.clone();
            let in_base = |i: u32| (i as usize) < c.sinks.min(w_start) || (i as usize) >= w_start;
            let mut hitters: Vec<(u32, f32)> = self.acc[p]
                .iter()
                .filter(|(&i, _)| !in_base(i))
                .map(|(&i, &s)| (i, s))
                .collect();
            hitters.sort_by(|a, b| b.1.total_cmp(&a.1));
            sel.extend(hitters.into_iter().take(c.budget).map(|(i, _)| i));
            sel.sort_unstable();
            per_plane.push(sel);
        }
        Selection { per_plane }
    }

    fn on_prefill(&mut self, ctx: &SelectCtx, colsum: &[f32], p_used: usize, t0: usize, t1: usize) {
        // colsum layout [L, H, P+T]: keys 0..t0 live in the past slots,
        // chunk keys t0..t1 in slots p_used..p_used+T.
        let c = ctx.cfg;
        let width = p_used + (t1 - t0);
        for p in 0..self.lh {
            let row = &colsum[p * width..(p + 1) * width];
            for j in 0..t0.min(p_used) {
                if !self.evicted[p].contains(&(j as u32)) {
                    *self.acc[p].entry(j as u32).or_insert(0.0) += row[j];
                }
            }
            for (off, j) in (t0..t1).enumerate() {
                *self.acc[p].entry(j as u32).or_insert(0.0) += row[p_used + off];
            }
            self.evict_overflow(p, c.budget + c.window + c.sinks);
        }
    }

    fn on_decode(&mut self, ctx: &SelectCtx, sel: &Selection, probs: &[f32], bucket_s: usize) {
        // probs layout [L, H, S+1]; map slot -> global token via sel.
        let c = ctx.cfg;
        let width = bucket_s + 1;
        for p in 0..self.lh {
            let row = &probs[p * width..(p + 1) * width];
            for (slot, &tok) in sel.per_plane[p].iter().enumerate() {
                if !self.evicted[p].contains(&tok) {
                    *self.acc[p].entry(tok).or_insert(0.0) += row[slot];
                }
            }
            // The new self token enters with its self-attention mass.
            *self.acc[p].entry((ctx.t - 1) as u32).or_insert(0.0) += row[bucket_s];
            self.evict_overflow(p, c.budget + c.window + c.sinks);
        }
    }
}

// ---------------------------------------------------------------------------
// SnapKV (Li et al. 2024): at the END of prefill, keep the prompt
// tokens with the highest pooled attention (observed by the final
// chunk's queries); frozen afterwards. Decode-time tokens join the
// sliding window only.
// ---------------------------------------------------------------------------

pub struct SnapKVPolicy {
    lh: usize,
    /// Latest prefill colsum snapshot per plane (token idx -> mass).
    snapshot: Vec<Vec<(u32, f32)>>,
    /// Frozen prompt selection (set at first decode).
    frozen: Option<Vec<Vec<u32>>>,
    prompt_len: usize,
}

impl SnapKVPolicy {
    pub fn new(lh: usize) -> Self {
        Self { lh, snapshot: vec![Vec::new(); lh], frozen: None, prompt_len: 0 }
    }
}

impl SelectionPolicy for SnapKVPolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::SnapKV
    }

    fn select(&mut self, ctx: &SelectCtx) -> Selection {
        let c = ctx.cfg;
        if self.frozen.is_none() {
            // Freeze: top-budget prompt tokens by the last chunk's pooling.
            let frozen = (0..self.lh)
                .map(|p| {
                    let mut v = self.snapshot[p].clone();
                    v.sort_by(|a, b| b.1.total_cmp(&a.1));
                    let mut idx: Vec<u32> =
                        v.into_iter().take(c.budget).map(|(i, _)| i).collect();
                    idx.sort_unstable();
                    idx
                })
                .collect();
            self.frozen = Some(frozen);
        }
        let frozen = self.frozen.as_ref().unwrap();
        let w_start = ctx.t.saturating_sub(c.window).max(self.prompt_len);
        let mut per_plane = Vec::with_capacity(self.lh);
        for p in 0..self.lh {
            let mut sel = sinks_and_window(c.sinks, w_start, ctx.t);
            sel.extend(
                frozen[p]
                    .iter()
                    .filter(|&&i| (i as usize) >= c.sinks && (i as usize) < w_start),
            );
            sel.sort_unstable();
            sel.dedup();
            per_plane.push(sel);
        }
        Selection { per_plane }
    }

    fn on_prefill(&mut self, _ctx: &SelectCtx, colsum: &[f32], p_used: usize, t0: usize, t1: usize) {
        // Keep only the latest chunk's pooling (SnapKV observes the
        // final window of prompt queries).
        let width = p_used + (t1 - t0);
        self.prompt_len = t1;
        for p in 0..self.lh {
            let row = &colsum[p * width..(p + 1) * width];
            let mut snap = Vec::with_capacity(t1);
            for j in 0..t0.min(p_used) {
                snap.push((j as u32, row[j]));
            }
            for (off, j) in (t0..t1).enumerate() {
                snap.push((j as u32, row[p_used + off]));
            }
            self.snapshot[p] = snap;
        }
    }
}

// ---------------------------------------------------------------------------
// SubGen-style (Zandieh et al. 2024), simplified: online k-means over
// key vectors; keep the token nearest each centroid + the window.
// Captures the cluster-then-sample KV compression idea.
// ---------------------------------------------------------------------------

pub struct SubGenPolicy {
    lh: usize,
    rng: SplitMix64,
    /// Per plane: (centroid vec, representative token, member count).
    centroids: Vec<Vec<(Vec<f32>, u32, usize)>>,
}

impl SubGenPolicy {
    pub fn new(lh: usize) -> Self {
        Self { lh, rng: SplitMix64::new(0xC0FFEE), centroids: vec![Vec::new(); lh] }
    }

    fn absorb(&mut self, ctx: &SelectCtx, t0: usize, t1: usize) {
        let cfg = ctx.pool.config();
        let max_c = ctx.cfg.budget.max(1);
        for l in 0..cfg.n_layers {
            for h in 0..cfg.n_heads {
                let p = l * cfg.n_heads + h;
                for tok in t0..t1 {
                    let key = ctx.seq.key(ctx.pool, l, h, tok).to_vec();
                    let cs = &mut self.centroids[p];
                    // Nearest centroid.
                    let mut best = None;
                    let mut best_d = f32::INFINITY;
                    for (i, (c, _, _)) in cs.iter().enumerate() {
                        let d: f32 =
                            c.iter().zip(&key).map(|(a, b)| (a - b) * (a - b)).sum();
                        if d < best_d {
                            best_d = d;
                            best = Some(i);
                        }
                    }
                    let spawn = cs.len() < max_c
                        && (cs.is_empty() || self.rng.below(4) == 0 || best_d > 2.0);
                    if spawn {
                        cs.push((key, tok as u32, 1));
                    } else if let Some(i) = best {
                        // Running-mean update; representative = closest seen.
                        let (c, rep, n) = &mut cs[i];
                        *n += 1;
                        let lr = 1.0 / *n as f32;
                        for (a, b) in c.iter_mut().zip(&key) {
                            *a += lr * (b - *a);
                        }
                        let d_rep: f32 = {
                            let rk = ctx.seq.key(ctx.pool, l, h, *rep as usize);
                            c.iter().zip(rk).map(|(a, b)| (a - b) * (a - b)).sum()
                        };
                        if best_d < d_rep {
                            *rep = tok as u32;
                        }
                    }
                }
            }
        }
    }
}

impl SelectionPolicy for SubGenPolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::SubGen
    }

    fn select(&mut self, ctx: &SelectCtx) -> Selection {
        let c = ctx.cfg;
        let w_start = ctx.t.saturating_sub(c.window);
        let mut per_plane = Vec::with_capacity(self.lh);
        for p in 0..self.lh {
            let mut sel = sinks_and_window(c.sinks, w_start, ctx.t);
            sel.extend(
                self.centroids[p]
                    .iter()
                    .map(|(_, rep, _)| *rep)
                    .filter(|&i| (i as usize) >= c.sinks && (i as usize) < w_start),
            );
            sel.sort_unstable();
            sel.dedup();
            per_plane.push(sel);
        }
        Selection { per_plane }
    }

    fn on_prefill(&mut self, ctx: &SelectCtx, _colsum: &[f32], _p: usize, t0: usize, t1: usize) {
        self.absorb(ctx, t0, t1);
    }

    fn on_decode(&mut self, ctx: &SelectCtx, _sel: &Selection, _probs: &[f32], _s: usize) {
        self.absorb(ctx, ctx.t - 1, ctx.t);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelConfig, ServingConfig};
    use crate::kvcache::{BlockPool, SeqCache};

    fn setup(t: usize) -> (BlockPool, SeqCache, ServingConfig) {
        let mc = ModelConfig {
            name: "t".into(),
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            d_head: 4,
            d_ffn: 16,
            n_feat: 8,
            max_train_len: 64,
            vocab: 16,
        };
        let mut pool = BlockPool::new(&mc, 8, 1000);
        let mut seq = SeqCache::new(8);
        let mut rng = SplitMix64::new(3);
        for _ in 0..t {
            let k: Vec<f32> = (0..16).map(|_| rng.next_f32()).collect();
            let f: Vec<f32> = (0..32).map(|_| rng.next_f32()).collect();
            seq.append(&mut pool, &k, &k.clone(), &f).unwrap();
        }
        let mut sc = ServingConfig::default();
        sc.sinks = 2;
        sc.window = 8;
        sc.budget = 4;
        (pool, seq, sc)
    }

    fn ctx<'a>(pool: &'a BlockPool, seq: &'a SeqCache, cfg: &'a ServingConfig, t: usize) -> SelectCtx<'a> {
        SelectCtx { pool, seq, t, cfg }
    }

    #[test]
    fn vanilla_selects_all() {
        let (pool, seq, sc) = setup(40);
        let mut p = VanillaPolicy::new(4);
        let s = p.select(&ctx(&pool, &seq, &sc, 40));
        assert_eq!(s.per_plane[0].len(), 40);
        assert_eq!(s.max_len(), 40);
    }

    #[test]
    fn streaming_is_sinks_plus_window() {
        let (pool, seq, sc) = setup(100);
        let mut p = StreamingPolicy::new(4);
        let s = p.select(&ctx(&pool, &seq, &sc, 100));
        // sinks 2 + span (window 8 + budget 4) = 14
        assert_eq!(s.per_plane[0].len(), 14);
        assert_eq!(&s.per_plane[0][..2], &[0, 1]);
        assert_eq!(*s.per_plane[0].last().unwrap(), 99);
        // never selects middle tokens
        assert!(!s.per_plane[0].contains(&50));
    }

    #[test]
    fn h2o_keeps_heavy_hitters_and_never_readmits() {
        let (pool, seq, sc) = setup(100);
        let mut p = H2OPolicy::new(4);
        let c = ctx(&pool, &seq, &sc, 100);
        // Fake decode feedback: token 30 gets huge mass on plane 0.
        let sel = Selection::uniform(4, (0..100u32).collect());
        let mut probs = vec![0.0f32; 4 * 101];
        probs[30] = 5.0;       // plane 0, slot 30 (= token 30)
        probs[101 + 60] = 3.0; // plane 1, token 60
        p.on_decode(&c, &sel, &probs, 100);
        let s = p.select(&c);
        assert!(s.per_plane[0].contains(&30), "heavy hitter kept on plane 0");
        assert!(s.per_plane[1].contains(&60), "plane-specific hitters");
        assert!(!s.per_plane[1].contains(&30) || probs[101 + 30] > 0.0);
        // Evict: flood with stronger hitters, then 30 must stay out.
        for step in 0..40 {
            let mut pr = vec![0.0f32; 4 * 101];
            pr[70 + (step % 10)] = 10.0;
            p.on_decode(&c, &sel, &pr, 100);
        }
        let evicted_contains_30 = p.evicted[0].contains(&30);
        if evicted_contains_30 {
            let mut pr = vec![0.0f32; 4 * 101];
            pr[30] = 100.0;
            p.on_decode(&c, &sel, &pr, 100);
            assert!(!p.acc[0].contains_key(&30), "evicted token must not re-enter");
        }
    }

    #[test]
    fn snapkv_freezes_prompt_selection() {
        let (pool, seq, sc) = setup(100);
        let mut p = SnapKVPolicy::new(4);
        let c = ctx(&pool, &seq, &sc, 100);
        // Prefill feedback: width = p_used 64 + chunk 16 = 80; token 10 hot.
        let mut colsum = vec![0.01f32; 4 * 80];
        colsum[10] = 9.0;
        p.on_prefill(&c, &colsum, 64, 64, 80);
        let s1 = p.select(&c);
        assert!(s1.per_plane[0].contains(&10));
        // Later feedback must NOT change the frozen selection.
        let mut colsum2 = vec![0.01f32; 4 * 80];
        colsum2[20] = 99.0;
        p.on_prefill(&c, &colsum2, 64, 64, 80);
        let s2 = p.select(&c);
        assert_eq!(s1.per_plane[0], s2.per_plane[0]);
    }

    #[test]
    fn subgen_selects_representatives_within_budget() {
        let (pool, seq, sc) = setup(100);
        let mut p = SubGenPolicy::new(4);
        let c = ctx(&pool, &seq, &sc, 100);
        p.on_prefill(&c, &[], 0, 0, 90);
        let s = p.select(&c);
        // window+sinks plus at most budget representatives
        assert!(s.per_plane[0].len() <= 2 + 8 + sc.budget);
        // all indices valid
        assert!(s.per_plane.iter().flatten().all(|&i| (i as usize) < 100));
    }

    #[test]
    fn all_selections_are_sorted_unique_valid() {
        let (pool, seq, sc) = setup(64);
        let c = ctx(&pool, &seq, &sc, 64);
        let sel = Selection::uniform(4, (0..64u32).collect());
        let probs = vec![0.001f32; 4 * 65];
        let colsum = vec![0.01f32; 4 * 64];
        let mut policies: Vec<Box<dyn SelectionPolicy>> = vec![
            Box::new(VanillaPolicy::new(4)),
            Box::new(StreamingPolicy::new(4)),
            Box::new(H2OPolicy::new(4)),
            Box::new(SnapKVPolicy::new(4)),
            Box::new(SubGenPolicy::new(4)),
        ];
        for p in &mut policies {
            p.on_prefill(&c, &colsum, 0, 0, 64);
            p.on_decode(&c, &sel, &probs, 64);
            let s = p.select(&c);
            for plane in &s.per_plane {
                let mut sorted = plane.clone();
                sorted.sort_unstable();
                sorted.dedup();
                assert_eq!(&sorted, plane, "{:?} selection must be sorted+unique", p.kind());
                assert!(plane.iter().all(|&i| (i as usize) < 64));
                assert!(!plane.is_empty());
            }
        }
    }
}
