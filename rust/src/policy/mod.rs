//! Token-selection policies. Every serving method — the paper's Radar
//! and all baselines — is a policy deciding, per (layer, head), which
//! cached token indices the next decode step attends to. The engine
//! gathers exactly those rows and runs the shared artifacts, so methods
//! differ *only* here (DESIGN.md §2).
//!
//! Two classes:
//! - query-independent (`select`): vanilla, StreamingLLM, H2O, SnapKV,
//!   SubGen — one selection for all layers/heads before the fused
//!   decode dispatch;
//! - query-dependent (`select_layer`): Radar and its ablations — called
//!   per layer with phi(q) (or q) in the per-layer pipeline.

mod baselines;
mod radar_policy;

pub use baselines::{H2OPolicy, SnapKVPolicy, StreamingPolicy, SubGenPolicy, VanillaPolicy};
pub use radar_policy::{RadarPolicy, RadarVariant};

use crate::config::{PolicyKind, ServingConfig};
use crate::kvcache::{BlockPool, SeqCache};

/// A per-(layer, head) index selection for one decode step.
/// `per_plane[p]` lists cache indices (ascending not required); all
/// planes attend through one padded buffer, masked per plane.
#[derive(Debug, Clone, Default)]
pub struct Selection {
    pub per_plane: Vec<Vec<u32>>,
}

impl Selection {
    pub fn uniform(lh: usize, idx: Vec<u32>) -> Self {
        Self { per_plane: vec![idx; lh] }
    }

    /// Max plane length == required S bucket.
    pub fn max_len(&self) -> usize {
        self.per_plane.iter().map(Vec::len).max().unwrap_or(0)
    }
}

/// Context handed to policies at selection time.
pub struct SelectCtx<'a> {
    pub pool: &'a BlockPool,
    pub seq: &'a SeqCache,
    /// Tokens currently cached (the next token gets position t).
    pub t: usize,
    pub cfg: &'a ServingConfig,
}

/// Query-independent policies (fused decode path).
pub trait SelectionPolicy: Send {
    fn kind(&self) -> PolicyKind;

    /// Selection for the next decode step (same for all planes or not —
    /// policy's choice), given the cache state.
    fn select(&mut self, ctx: &SelectCtx) -> Selection;

    /// Feedback: prefill chunk processed. `colsum[l][h][j]` is attention
    /// mass received by key j (layout [L, H, P+T]), `p_used` the past
    /// bucket, `t0`/`t1` the chunk's token range.
    fn on_prefill(&mut self, _ctx: &SelectCtx, _colsum: &[f32], _p_used: usize, _t0: usize, _t1: usize) {}

    /// Feedback: decode step done. `sel` is the selection that produced
    /// `probs` (layout [L, H, S+1], slot S = the new self token).
    fn on_decode(&mut self, _ctx: &SelectCtx, _sel: &Selection, _probs: &[f32], _bucket_s: usize) {}

    /// Whether a sequence running this policy may skip prefilling a
    /// shared prompt prefix (KV blocks seeded from the prefix cache).
    /// Only stateless policies — no `on_prefill` accumulation — can
    /// safely skip the chunks; stateful ones (H2O, SnapKV, SubGen)
    /// would miss the attention-mass feedback those chunks feed them.
    fn prefix_reuse_safe(&self) -> bool {
        false
    }
}

/// Instantiate the policy object for a request.
pub fn make_policy(cfg: &ServingConfig, lh: usize) -> Box<dyn SelectionPolicy> {
    match cfg.policy {
        PolicyKind::Vanilla => Box::new(VanillaPolicy::new(lh)),
        PolicyKind::Streaming => Box::new(StreamingPolicy::new(lh)),
        PolicyKind::H2O => Box::new(H2OPolicy::new(lh)),
        PolicyKind::SnapKV => Box::new(SnapKVPolicy::new(lh)),
        PolicyKind::SubGen => Box::new(SubGenPolicy::new(lh)),
        // Radar variants run on the per-layer pipeline and are
        // constructed separately (RadarPolicy::new); the engine checks
        // `is_query_dependent` first. This arm exists so harnesses can
        // still construct them uniformly for non-decode bookkeeping.
        PolicyKind::Radar | PolicyKind::RadarExact | PolicyKind::RadarRandom
        | PolicyKind::RadarLowest => {
            unreachable!("radar policies use the per-layer pipeline")
        }
    }
}

pub fn is_query_dependent(kind: PolicyKind) -> bool {
    matches!(
        kind,
        PolicyKind::Radar
            | PolicyKind::RadarExact
            | PolicyKind::RadarRandom
            | PolicyKind::RadarLowest
    )
}

/// Shared helper: sinks [0, sinks) plus window [w_start, t).
pub fn sinks_and_window(sinks: usize, w_start: usize, t: usize) -> Vec<u32> {
    let s_end = sinks.min(t).min(w_start);
    let mut out: Vec<u32> = (0..s_end as u32).collect();
    out.extend(w_start as u32..t as u32);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selection_uniform_and_maxlen() {
        let s = Selection::uniform(3, vec![1, 2, 3]);
        assert_eq!(s.per_plane.len(), 3);
        assert_eq!(s.max_len(), 3);
        let mut s2 = s.clone();
        s2.per_plane[1].push(9);
        assert_eq!(s2.max_len(), 4);
    }

    #[test]
    fn sinks_window_no_overlap() {
        // window starts inside the sink range -> sinks truncated
        assert_eq!(sinks_and_window(4, 2, 6), vec![0, 1, 2, 3, 4, 5]);
        // normal case
        assert_eq!(sinks_and_window(2, 8, 10), vec![0, 1, 8, 9]);
        // tiny context
        assert_eq!(sinks_and_window(4, 0, 2), vec![0, 1]);
    }

    #[test]
    fn query_dependence_partition() {
        use crate::config::PolicyKind::*;
        for k in [Vanilla, Streaming, H2O, SnapKV, SubGen] {
            assert!(!is_query_dependent(k));
        }
        for k in [Radar, RadarExact, RadarRandom, RadarLowest] {
            assert!(is_query_dependent(k));
        }
    }
}
