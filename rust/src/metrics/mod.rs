//! Serving metrics: counters + latency histograms with a text
//! exposition (the `/metrics` endpoint and per-run summaries).

use crate::util::stats::Series;
use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    latencies_us: BTreeMap<String, Series>,
    /// Unitless value distributions (e.g. prefill tokens saved per
    /// request) — same Series machinery, separate exposition prefix.
    histograms: BTreeMap<String, Series>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&self, name: &str) {
        self.add(name, 1);
    }

    pub fn add(&self, name: &str, delta: u64) {
        let mut g = self.inner.lock().unwrap();
        *g.counters.entry(name.to_string()).or_insert(0) += delta;
    }

    /// Set a point-in-time value (queue depth, blocks in use, ...).
    pub fn set_gauge(&self, name: &str, value: f64) {
        let mut g = self.inner.lock().unwrap();
        g.gauges.insert(name.to_string(), value);
    }

    pub fn gauge(&self, name: &str) -> f64 {
        self.inner.lock().unwrap().gauges.get(name).copied().unwrap_or(0.0)
    }

    pub fn observe_us(&self, name: &str, us: f64) {
        let mut g = self.inner.lock().unwrap();
        g.latencies_us.entry(name.to_string()).or_default().push(us);
    }

    /// Time a closure into the named latency series.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        self.observe_us(name, t.elapsed().as_secs_f64() * 1e6);
        out
    }

    /// Record one sample into the named histogram.
    pub fn observe(&self, name: &str, value: f64) {
        let mut g = self.inner.lock().unwrap();
        g.histograms.entry(name.to_string()).or_default().push(value);
    }

    pub fn histogram_count(&self, name: &str) -> usize {
        self.inner.lock().unwrap().histograms.get(name).map(|s| s.len()).unwrap_or(0)
    }

    /// Mean of the named histogram; an empty or missing histogram is 0,
    /// never NaN, so dashboards and summaries render cleanly.
    pub fn histogram_mean(&self, name: &str) -> f64 {
        self.inner
            .lock()
            .unwrap()
            .histograms
            .get(name)
            .filter(|s| !s.is_empty())
            .map(|s| s.mean())
            .unwrap_or(0.0)
    }

    /// Percentile (`p` in [0, 100]) of the named histogram; an empty or
    /// missing histogram is 0, never NaN.
    pub fn histogram_percentile(&self, name: &str, p: f64) -> f64 {
        self.inner
            .lock()
            .unwrap()
            .histograms
            .get(name)
            .filter(|s| !s.is_empty())
            .map(|s| s.percentile(p))
            .unwrap_or(0.0)
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.inner.lock().unwrap().counters.get(name).copied().unwrap_or(0)
    }

    /// All counters in deterministic (lexicographic) order — the
    /// BTreeMap ordering, independent of insertion order.
    pub fn counters_snapshot(&self) -> Vec<(String, u64)> {
        let g = self.inner.lock().unwrap();
        g.counters.iter().map(|(k, v)| (k.clone(), *v)).collect()
    }

    /// Mean of the named latency series; empty/missing is 0, not NaN.
    pub fn latency_mean_us(&self, name: &str) -> f64 {
        self.inner
            .lock()
            .unwrap()
            .latencies_us
            .get(name)
            .filter(|s| !s.is_empty())
            .map(|s| s.mean())
            .unwrap_or(0.0)
    }

    pub fn latency_count(&self, name: &str) -> usize {
        self.inner
            .lock()
            .unwrap()
            .latencies_us
            .get(name)
            .map(|s| s.len())
            .unwrap_or(0)
    }

    /// Plain-text exposition (one metric per line).
    pub fn render(&self) -> String {
        let g = self.inner.lock().unwrap();
        let mut out = String::new();
        for (k, v) in &g.counters {
            out.push_str(&format!("counter {k} {v}\n"));
        }
        for (k, v) in &g.gauges {
            out.push_str(&format!("gauge {k} {v}\n"));
        }
        for (k, s) in &g.latencies_us {
            out.push_str(&format!(
                "latency_us {k} count {} mean {:.1} p50 {:.1} p99 {:.1}\n",
                s.len(),
                s.mean(),
                s.p50(),
                s.p99(),
            ));
        }
        for (k, s) in &g.histograms {
            out.push_str(&format!(
                "histogram {k} count {} mean {:.1} p50 {:.1} p99 {:.1}\n",
                s.len(),
                s.mean(),
                s.p50(),
                s.p99(),
            ));
        }
        out
    }

    pub fn reset(&self) {
        let mut g = self.inner.lock().unwrap();
        g.counters.clear();
        g.gauges.clear();
        g.latencies_us.clear();
        g.histograms.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.inc("tokens");
        m.add("tokens", 4);
        assert_eq!(m.counter("tokens"), 5);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn latency_series() {
        let m = Metrics::new();
        for i in 0..10 {
            m.observe_us("step", i as f64);
        }
        assert_eq!(m.latency_count("step"), 10);
        assert!((m.latency_mean_us("step") - 4.5).abs() < 1e-9);
    }

    #[test]
    fn time_records() {
        let m = Metrics::new();
        let x = m.time("work", || 42);
        assert_eq!(x, 42);
        assert_eq!(m.latency_count("work"), 1);
    }

    #[test]
    fn render_contains_all() {
        let m = Metrics::new();
        m.inc("a");
        m.observe_us("b", 1.0);
        m.set_gauge("c", 2.5);
        let r = m.render();
        assert!(r.contains("counter a 1"));
        assert!(r.contains("latency_us b"));
        assert!(r.contains("gauge c 2.5"));
    }

    #[test]
    fn histograms_record_and_render() {
        let m = Metrics::new();
        for v in [10.0, 20.0, 30.0] {
            m.observe("prefill_tokens_saved", v);
        }
        assert_eq!(m.histogram_count("prefill_tokens_saved"), 3);
        assert!((m.histogram_mean("prefill_tokens_saved") - 20.0).abs() < 1e-9);
        assert_eq!(m.histogram_count("missing"), 0);
        let r = m.render();
        assert!(r.contains("histogram prefill_tokens_saved count 3"));
        m.reset();
        assert_eq!(m.histogram_count("prefill_tokens_saved"), 0);
    }

    #[test]
    fn empty_histogram_reads_zero_not_nan() {
        let m = Metrics::new();
        // Missing series: queries return 0 and render stays finite.
        assert_eq!(m.histogram_mean("missing"), 0.0);
        assert_eq!(m.histogram_percentile("missing", 50.0), 0.0);
        assert_eq!(m.histogram_percentile("missing", 99.0), 0.0);
        assert_eq!(m.latency_mean_us("missing"), 0.0);
        // Present series: percentiles come from the data.
        for v in [1.0, 2.0, 3.0, 4.0] {
            m.observe("h", v);
        }
        assert!(m.histogram_percentile("h", 50.0) >= 1.0);
        assert!(m.histogram_percentile("h", 100.0) <= 4.0);
        assert!(m.histogram_mean("h").is_finite());
    }

    #[test]
    fn counter_snapshot_order_is_deterministic() {
        let m = Metrics::new();
        // Insertion order deliberately scrambled; snapshot must sort.
        m.inc("zeta");
        m.inc("alpha");
        m.add("midway", 3);
        let snap = m.counters_snapshot();
        let names: Vec<&str> = snap.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(names, vec!["alpha", "midway", "zeta"]);
        assert_eq!(snap[2], ("zeta".to_string(), 1));
        let again = m.counters_snapshot();
        assert_eq!(snap, again, "same state must snapshot identically");
    }

    #[test]
    fn gauges_overwrite() {
        let m = Metrics::new();
        m.set_gauge("depth", 3.0);
        m.set_gauge("depth", 1.0);
        assert_eq!(m.gauge("depth"), 1.0);
        assert_eq!(m.gauge("missing"), 0.0);
    }
}
