//! Serving metrics: counters + latency histograms with a text
//! exposition (the `/metrics` endpoint and per-run summaries).

use crate::util::stats::Series;
use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    latencies_us: BTreeMap<String, Series>,
    /// Unitless value distributions (e.g. prefill tokens saved per
    /// request) — same Series machinery, separate exposition prefix.
    histograms: BTreeMap<String, Series>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&self, name: &str) {
        self.add(name, 1);
    }

    pub fn add(&self, name: &str, delta: u64) {
        let mut g = self.inner.lock().unwrap();
        *g.counters.entry(name.to_string()).or_insert(0) += delta;
    }

    /// Set a point-in-time value (queue depth, blocks in use, ...).
    pub fn set_gauge(&self, name: &str, value: f64) {
        let mut g = self.inner.lock().unwrap();
        g.gauges.insert(name.to_string(), value);
    }

    pub fn gauge(&self, name: &str) -> f64 {
        self.inner.lock().unwrap().gauges.get(name).copied().unwrap_or(0.0)
    }

    pub fn observe_us(&self, name: &str, us: f64) {
        let mut g = self.inner.lock().unwrap();
        g.latencies_us.entry(name.to_string()).or_default().push(us);
    }

    /// Time a closure into the named latency series.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        self.observe_us(name, t.elapsed().as_secs_f64() * 1e6);
        out
    }

    /// Record one sample into the named histogram.
    pub fn observe(&self, name: &str, value: f64) {
        let mut g = self.inner.lock().unwrap();
        g.histograms.entry(name.to_string()).or_default().push(value);
    }

    pub fn histogram_count(&self, name: &str) -> usize {
        self.inner.lock().unwrap().histograms.get(name).map(|s| s.len()).unwrap_or(0)
    }

    pub fn histogram_mean(&self, name: &str) -> f64 {
        self.inner
            .lock()
            .unwrap()
            .histograms
            .get(name)
            .map(|s| s.mean())
            .unwrap_or(f64::NAN)
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.inner.lock().unwrap().counters.get(name).copied().unwrap_or(0)
    }

    pub fn latency_mean_us(&self, name: &str) -> f64 {
        self.inner
            .lock()
            .unwrap()
            .latencies_us
            .get(name)
            .map(|s| s.mean())
            .unwrap_or(f64::NAN)
    }

    pub fn latency_count(&self, name: &str) -> usize {
        self.inner
            .lock()
            .unwrap()
            .latencies_us
            .get(name)
            .map(|s| s.len())
            .unwrap_or(0)
    }

    /// Plain-text exposition (one metric per line).
    pub fn render(&self) -> String {
        let g = self.inner.lock().unwrap();
        let mut out = String::new();
        for (k, v) in &g.counters {
            out.push_str(&format!("counter {k} {v}\n"));
        }
        for (k, v) in &g.gauges {
            out.push_str(&format!("gauge {k} {v}\n"));
        }
        for (k, s) in &g.latencies_us {
            out.push_str(&format!(
                "latency_us {k} count {} mean {:.1} p50 {:.1} p99 {:.1}\n",
                s.len(),
                s.mean(),
                s.p50(),
                s.p99(),
            ));
        }
        for (k, s) in &g.histograms {
            out.push_str(&format!(
                "histogram {k} count {} mean {:.1} p50 {:.1} p99 {:.1}\n",
                s.len(),
                s.mean(),
                s.p50(),
                s.p99(),
            ));
        }
        out
    }

    pub fn reset(&self) {
        let mut g = self.inner.lock().unwrap();
        g.counters.clear();
        g.gauges.clear();
        g.latencies_us.clear();
        g.histograms.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.inc("tokens");
        m.add("tokens", 4);
        assert_eq!(m.counter("tokens"), 5);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn latency_series() {
        let m = Metrics::new();
        for i in 0..10 {
            m.observe_us("step", i as f64);
        }
        assert_eq!(m.latency_count("step"), 10);
        assert!((m.latency_mean_us("step") - 4.5).abs() < 1e-9);
    }

    #[test]
    fn time_records() {
        let m = Metrics::new();
        let x = m.time("work", || 42);
        assert_eq!(x, 42);
        assert_eq!(m.latency_count("work"), 1);
    }

    #[test]
    fn render_contains_all() {
        let m = Metrics::new();
        m.inc("a");
        m.observe_us("b", 1.0);
        m.set_gauge("c", 2.5);
        let r = m.render();
        assert!(r.contains("counter a 1"));
        assert!(r.contains("latency_us b"));
        assert!(r.contains("gauge c 2.5"));
    }

    #[test]
    fn histograms_record_and_render() {
        let m = Metrics::new();
        for v in [10.0, 20.0, 30.0] {
            m.observe("prefill_tokens_saved", v);
        }
        assert_eq!(m.histogram_count("prefill_tokens_saved"), 3);
        assert!((m.histogram_mean("prefill_tokens_saved") - 20.0).abs() < 1e-9);
        assert_eq!(m.histogram_count("missing"), 0);
        assert!(m.histogram_mean("missing").is_nan());
        let r = m.render();
        assert!(r.contains("histogram prefill_tokens_saved count 3"));
        m.reset();
        assert_eq!(m.histogram_count("prefill_tokens_saved"), 0);
    }

    #[test]
    fn gauges_overwrite() {
        let m = Metrics::new();
        m.set_gauge("depth", 3.0);
        m.set_gauge("depth", 1.0);
        assert_eq!(m.gauge("depth"), 1.0);
        assert_eq!(m.gauge("missing"), 0.0);
    }
}
