//! Crash-recovery suite: the durable session journal under real and
//! simulated crashes.
//!
//! The invariants under test (ISSUE acceptance criteria):
//!   (a) for any injected `crash@STEP` fault, a restarted engine on the
//!       same journal directory re-admits every unfinished session and
//!       emits exactly the token suffix an uncrashed run would have
//!       produced (byte-identical full streams),
//!   (b) a torn or corrupt journal tail is truncated, never fatal —
//!       including tails left by a real `kill -9` mid-append (the suite
//!       re-execs itself as a writer child and SIGKILLs it in a loop),
//!   (c) SSE stream resume via `Last-Event-ID` replays with no gaps
//!       and no duplicates.
//!
//! Crash specs for the fault matrix come from `CRASH_SPECS`
//! (';'-separated `crash@STEP[:SEQ]` plans; CI runs a matrix).
//! Engine/server tests self-skip without `make artifacts`; the journal
//! and SIGKILL tests are pure and always run.

use radar_serve::config::{ArtifactPaths, PolicyKind, ServingConfig};
use radar_serve::engine::{Engine, FinishReason, GenRequest, Priority, SessionResult};
use radar_serve::faults::FaultPlan;
use radar_serve::metrics::Metrics;
use radar_serve::model::tokenizer;
use radar_serve::recovery::{AdmitRecord, Journal, Terminal};
use radar_serve::runtime::Runtime;
use radar_serve::util::json::Json;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

fn runtime() -> Option<Arc<Runtime>> {
    let paths = ArtifactPaths::new("artifacts", "sm");
    if !paths.manifest().exists() {
        eprintln!("skipping recovery engine tests: run `make artifacts` first");
        return None;
    }
    Some(Arc::new(Runtime::load(paths).unwrap()))
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("radar-recovery-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn engine_with(
    rt: Arc<Runtime>,
    policy: PolicyKind,
    tweak: impl FnOnce(&mut ServingConfig),
) -> Engine {
    let mut cfg = ServingConfig::default();
    cfg.policy = policy;
    cfg.window = 32;
    cfg.budget = 64;
    tweak(&mut cfg);
    Engine::new(rt, cfg).unwrap()
}

/// Step until idle, bounded so a scheduling bug fails loudly instead
/// of hanging the suite.
fn drive(e: &mut Engine, max_steps: usize) {
    let mut n = 0;
    while !e.idle() {
        e.step().unwrap();
        n += 1;
        assert!(n < max_steps, "engine did not go idle within {max_steps} steps");
    }
}

const PROMPTS: [&str; 3] = ["the stream carries ", "old light towards ", "quiet hills answer "];

/// The standard request trio. Session 2 samples non-greedily with a
/// pinned seed: recovery must fast-forward its deterministic sampler
/// past the journaled draws to keep the suffix byte-identical.
fn requests(max_new: usize) -> Vec<GenRequest> {
    let mut reqs: Vec<GenRequest> =
        PROMPTS.iter().map(|p| GenRequest::new(tokenizer::encode(p), max_new)).collect();
    reqs[1].greedy = Some(false);
    reqs[1].temperature = Some(0.8);
    reqs[1].seed = Some(123);
    reqs
}

/// Submit all requests (ids 1..=n), run to idle, collect in order.
fn run_all(e: &mut Engine, reqs: Vec<GenRequest>) -> Vec<SessionResult> {
    let handles: Vec<_> = reqs.into_iter().map(|r| e.submit(r).unwrap()).collect();
    drive(e, 500);
    handles.iter().map(|h| h.collect()).collect()
}

// ---------------------------------------------------------------------
// Journal durability (pure: no artifacts needed)
// ---------------------------------------------------------------------

/// A minimal admission record for journal-only tests; `max_new_tokens`
/// is huge so the session never looks terminal.
fn writer_admit(id: u64) -> AdmitRecord {
    AdmitRecord {
        id,
        seed: 7,
        temperature: 0.0,
        greedy: true,
        prompt: vec![104, 105],
        max_new_tokens: 1 << 40,
        stop_token: None,
        timeout_ms: None,
        prefix_cache: true,
        priority: Priority::Normal,
        teacher: None,
    }
}

#[test]
fn torn_tail_is_truncated_not_fatal_across_reopen() {
    let dir = tmp_dir("torn");
    let dir_s = dir.to_string_lossy().into_owned();
    {
        let j = Journal::open(&dir_s, 1, Arc::new(Metrics::new())).unwrap();
        j.admit(&writer_admit(1));
        j.step(1, 0, 42, -0.5);
        j.finish(1, Terminal::Stop);
        j.admit(&writer_admit(2));
        j.step(2, 0, 7, -0.25);
    }
    // A crash mid-append: the frame header promises more bytes than
    // exist on disk.
    let path = dir.join("journal.0.bin");
    let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
    f.write_all(&[0x40, 0x00, 0x00, 0x00, 0xde, 0xad, 0xbe]).unwrap();
    drop(f);
    let m = Arc::new(Metrics::new());
    let j = Journal::open(&dir_s, 1, m.clone()).unwrap();
    assert_eq!(m.counter("journal_torn_tail"), 1);
    let open = j.unfinished_sessions();
    assert_eq!(open.len(), 1, "every clean record must survive the torn tail");
    assert_eq!(open[0].admit.id, 2);
    assert_eq!(open[0].tokens, vec![7]);
    assert_eq!(j.mirror().get(1).unwrap().finish, Some(Terminal::Stop));
    drop(j);
    // The tail was physically removed: the next open sees a clean file.
    let m2 = Arc::new(Metrics::new());
    let j = Journal::open(&dir_s, 1, m2.clone()).unwrap();
    assert_eq!(m2.counter("journal_torn_tail"), 0);
    assert_eq!(j.unfinished_sessions().len(), 1);
    drop(j);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Writer child for the SIGKILL loop below: re-executed from the test
/// binary with `RECOVERY_WRITER_DIR` set, it appends STEP records with
/// a predictable token pattern until the parent kills it. Without the
/// env var (a normal test run) it is a no-op.
#[test]
fn sigkill_writer_child() {
    let Ok(dir) = std::env::var("RECOVERY_WRITER_DIR") else { return };
    let id: u64 = std::env::var("RECOVERY_WRITER_ID")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1);
    let j = Journal::open(&dir, 8, Arc::new(Metrics::new())).unwrap();
    j.admit(&writer_admit(id));
    let mut i = j.mirror().get(id).map(|s| s.tokens.len()).unwrap_or(0);
    loop {
        j.step(id, i, (i % 251) as i32, -0.5);
        i += 1;
    }
}

#[test]
fn sigkill_loop_leaves_recoverable_journal() {
    let dir = tmp_dir("sigkill");
    let dir_s = dir.to_string_lossy().into_owned();
    let path = dir.join("journal.0.bin");
    let exe = std::env::current_exe().unwrap();
    for attempt in 1..=3u64 {
        let base = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
        let mut child = std::process::Command::new(&exe)
            .args(["--exact", "sigkill_writer_child", "--nocapture"])
            .env("RECOVERY_WRITER_DIR", &dir_s)
            .env("RECOVERY_WRITER_ID", attempt.to_string())
            .stdout(std::process::Stdio::null())
            .stderr(std::process::Stdio::null())
            .spawn()
            .unwrap();
        // Let the writer demonstrably append before pulling the plug.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
        loop {
            let len = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
            if len > base + 128 {
                break;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "attempt {attempt}: writer child made no progress"
            );
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        child.kill().unwrap(); // SIGKILL on unix: no destructors, no flush
        child.wait().unwrap();

        // The journal must recover to a consistent state: every session
        // admitted so far present, tokens a contiguous prefix of the
        // writer's pattern, and the file appendable again.
        let j = Journal::open(&dir_s, 1, Arc::new(Metrics::new())).unwrap();
        for id in 1..=attempt {
            let st = j
                .mirror()
                .get(id)
                .unwrap_or_else(|| panic!("attempt {attempt}: session {id} lost"));
            assert!(st.finish.is_none());
            assert!(!st.tokens.is_empty(), "attempt {attempt}: no steps survived for {id}");
            for (i, &t) in st.tokens.iter().enumerate() {
                assert_eq!(
                    t,
                    (i % 251) as i32,
                    "attempt {attempt} session {id}: stream corrupted at index {i}"
                );
            }
        }
        // Append after truncation, keeping the pattern so the next
        // attempt's verification covers this record too.
        let n = j.mirror().get(attempt).unwrap().tokens.len();
        j.step(attempt, n, (n % 251) as i32, -0.5);
        drop(j);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// crash@STEP fault matrix: byte-identical recovery (needs artifacts)
// ---------------------------------------------------------------------

#[test]
fn crash_fault_recovery_is_byte_identical() {
    let Some(rt) = runtime() else { return };
    let specs = std::env::var("CRASH_SPECS").unwrap_or_else(|_| "crash@3;crash@5:2".into());
    for (si, spec) in specs.split(';').map(str::trim).filter(|s| !s.is_empty()).enumerate() {
        // Alternate pipelines so both decode paths see crash faults.
        let policy = if si % 2 == 0 { PolicyKind::Radar } else { PolicyKind::Streaming };
        let mut base = engine_with(rt.clone(), policy, |_| {});
        let baseline = run_all(&mut base, requests(12));
        for (i, r) in baseline.iter().enumerate() {
            assert!(r.error.is_none(), "baseline seq {}: {:?}", i + 1, r.error);
            assert_eq!(r.tokens.len(), 12, "baseline seq {}", i + 1);
        }
        drop(base);

        // fsync_every=1 keeps every record durable; fsync_every=4 loses
        // the unsynced tail at the crash, which recovery must
        // *regenerate* identically. The second config also checkpoints
        // mid-run to cover epoch rotation.
        for (fsync_every, ckpt) in [(1usize, 0u64), (4, 5)] {
            let dir = tmp_dir(&format!("crash{si}-{fsync_every}"));
            let dir_s = dir.to_string_lossy().into_owned();
            let plan = FaultPlan::parse(spec)
                .unwrap_or_else(|e| panic!("bad CRASH_SPECS entry {spec:?}: {e}"));
            let ds = dir_s.clone();
            let mut e1 = engine_with(rt.clone(), policy, move |c| {
                c.journal_dir = ds;
                c.journal_fsync_every = fsync_every;
                c.checkpoint_interval_steps = ckpt;
                c.faults = Some(plan);
            });
            let crashed = run_all(&mut e1, requests(12));
            let crash_fired = crashed
                .iter()
                .any(|r| r.error.as_deref().is_some_and(|m| m.contains("crash")));
            assert!(e1.idle(), "spec {spec}: engine not idle after the run");
            drop(e1);
            if !crash_fired {
                // Spec step past this run's horizon: nothing crashed,
                // so the run must simply match the baseline.
                for (i, r) in crashed.iter().enumerate() {
                    assert_eq!(r.tokens, baseline[i].tokens, "spec {spec}: crash-free run diverged");
                }
                let _ = std::fs::remove_dir_all(&dir);
                continue;
            }

            // "Restart": a fresh engine over the same journal dir.
            let ds = dir_s.clone();
            let mut e2 = engine_with(rt.clone(), policy, move |c| {
                c.journal_dir = ds;
                c.journal_fsync_every = 1;
            });
            let report = e2.recover();
            assert!(!report.sessions.is_empty(), "spec {spec}: nothing recovered");
            assert_eq!(
                e2.metrics.counter("recovered_sessions"),
                report.sessions.len() as u64
            );
            drive(&mut e2, 500);
            for h in &report.sessions {
                let out = h.collect();
                assert!(out.error.is_none(), "spec {spec} seq {}: {:?}", h.id, out.error);
                assert_eq!(out.finish, Some(FinishReason::Length), "spec {spec} seq {}", h.id);
                // The recovered handle carries exactly the remaining
                // suffix of the uncrashed stream.
                let b = &baseline[(h.id - 1) as usize];
                assert!(
                    b.tokens.ends_with(&out.tokens),
                    "spec {spec} seq {}: recovered suffix diverged from baseline",
                    h.id
                );
            }
            // Journaled prefix + recovered suffix == the uncrashed
            // stream, byte for byte, for every session.
            let mirror = e2.journal_mirror().unwrap();
            for (i, b) in baseline.iter().enumerate() {
                let st = mirror.get(i as u64 + 1).unwrap();
                assert_eq!(
                    st.tokens,
                    b.tokens,
                    "spec {spec} seq {}: full stream not byte-identical",
                    i + 1
                );
            }
            assert!(e2.metrics.counter("replay_tokens") > 0 || report.replayed_tokens == 0);
            assert_eq!(
                e2.pool.used_blocks(),
                e2.prefix.cached_blocks(),
                "spec {spec}: kv blocks leaked across recovery"
            );
            let _ = std::fs::remove_dir_all(&dir);
        }
    }
}

// ---------------------------------------------------------------------
// SSE stream resume over HTTP (needs artifacts)
// ---------------------------------------------------------------------

const ADDR: &str = "127.0.0.1:18913";

fn post_completions(writer: &mut TcpStream, body: &str) -> anyhow::Result<()> {
    write!(
        writer,
        "POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        body
    )?;
    Ok(())
}

fn http_get(path: &str, extra_headers: &str) -> anyhow::Result<String> {
    let mut s = TcpStream::connect(ADDR)?;
    write!(s, "GET {path} HTTP/1.1\r\nHost: x\r\n{extra_headers}Connection: close\r\n\r\n")?;
    let mut out = String::new();
    s.read_to_string(&mut out)?;
    Ok(out)
}

/// Parse an SSE response into `(id, text)` events, the un-id'd tail
/// text (final finish chunk), and the finish reason.
fn sse_events(raw: &str) -> (Vec<(u64, String)>, String, Option<String>) {
    let mut events = Vec::new();
    let mut tail = String::new();
    let mut finish = None;
    let mut cur_id: Option<u64> = None;
    for line in raw.lines() {
        if let Some(v) = line.strip_prefix("id: ") {
            cur_id = v.trim().parse().ok();
            continue;
        }
        let Some(payload) = line.strip_prefix("data: ") else { continue };
        if payload == "[DONE]" {
            break;
        }
        let j = Json::parse(payload).unwrap();
        let choice = &j.get("choices").unwrap().as_arr().unwrap()[0];
        let text = choice.get("text").and_then(Json::as_str).unwrap_or("").to_string();
        if let Some(f) = choice.get("finish_reason").and_then(Json::as_str) {
            finish = Some(f.to_string());
        }
        match cur_id.take() {
            Some(id) => events.push((id, text)),
            None => tail.push_str(&text),
        }
    }
    (events, tail, finish)
}

fn resume_driver() -> anyhow::Result<()> {
    for _ in 0..200 {
        if TcpStream::connect(ADDR).is_ok() {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
    }

    // Live stream: every token chunk must carry its 0-based event id.
    let body = Json::obj()
        .with("prompt", "the stream carries old light towards dawn. quiet hills ")
        .with("max_tokens", 12usize)
        .with("seed", 7usize)
        .with("stream", true)
        .to_string();
    let mut s = TcpStream::connect(ADDR)?;
    post_completions(&mut s, &body)?;
    let mut raw = String::new();
    s.read_to_string(&mut raw)?; // SSE is close-delimited
    anyhow::ensure!(raw.starts_with("HTTP/1.1 200"), "live stream: {raw}");
    let (events, tail, finish) = sse_events(&raw);
    anyhow::ensure!(finish.as_deref() == Some("length"), "live finish: {finish:?}");
    let ids: Vec<u64> = events.iter().map(|(i, _)| *i).collect();
    anyhow::ensure!(ids == (0u64..12).collect::<Vec<u64>>(), "live event ids: {ids:?}");
    let full_text: String =
        events.iter().map(|(_, t)| t.as_str()).collect::<String>() + &tail;

    // Status endpoint: the journaled session is queryable after finish.
    {
        let resp = http_get("/v1/sessions/1", "")?;
        anyhow::ensure!(resp.starts_with("HTTP/1.1 200"), "status: {resp}");
        let body = resp.split_once("\r\n\r\n").map(|(_, b)| b).unwrap_or("");
        let j = Json::parse(body)?;
        anyhow::ensure!(
            j.get("status").and_then(Json::as_str) == Some("length"),
            "status body: {body}"
        );
        anyhow::ensure!(
            j.get("tokens").and_then(Json::as_usize) == Some(12),
            "status tokens: {body}"
        );
        anyhow::ensure!(
            j.get("prompt_tokens").and_then(Json::as_usize).unwrap_or(0) > 0,
            "status prompt_tokens: {body}"
        );
    }
    // Unknown session -> 404; wrong method -> 405.
    {
        let resp = http_get("/v1/sessions/999", "")?;
        anyhow::ensure!(resp.starts_with("HTTP/1.1 404"), "unknown session: {resp}");
        let mut s = TcpStream::connect(ADDR)?;
        write!(
            s,
            "POST /v1/sessions/1 HTTP/1.1\r\nHost: x\r\nContent-Length: 0\r\nConnection: close\r\n\r\n"
        )?;
        let mut out = String::new();
        s.read_to_string(&mut out)?;
        anyhow::ensure!(out.starts_with("HTTP/1.1 405"), "POST session: {out}");
    }

    // Resume from Last-Event-ID: 5 -> ids 6..=11, no gaps, no dups.
    let raw2 = http_get("/v1/sessions/1/stream", "Last-Event-ID: 5\r\n")?;
    anyhow::ensure!(raw2.starts_with("HTTP/1.1 200"), "resume: {raw2}");
    anyhow::ensure!(raw2.contains("text/event-stream"), "resume headers: {raw2}");
    anyhow::ensure!(raw2.trim_end().ends_with("data: [DONE]"), "resume end: {raw2}");
    let (ev2, tail2, fin2) = sse_events(&raw2);
    anyhow::ensure!(fin2.as_deref() == Some("length"), "resume finish: {fin2:?}");
    let ids2: Vec<u64> = ev2.iter().map(|(i, _)| *i).collect();
    anyhow::ensure!(ids2 == (6u64..12).collect::<Vec<u64>>(), "resume event ids: {ids2:?}");
    if full_text.is_ascii() {
        let skip: usize = events.iter().filter(|(i, _)| *i <= 5).map(|(_, t)| t.len()).sum();
        let replay: String = ev2.iter().map(|(_, t)| t.as_str()).collect::<String>() + &tail2;
        anyhow::ensure!(
            replay == full_text[skip..],
            "resume text {replay:?} != live suffix {:?}",
            &full_text[skip..]
        );
    }

    // A fresh replay with no Last-Event-ID starts from token 0.
    let raw3 = http_get("/v1/sessions/1/stream", "")?;
    let (ev3, tail3, fin3) = sse_events(&raw3);
    anyhow::ensure!(fin3.as_deref() == Some("length"), "replay finish: {fin3:?}");
    let ids3: Vec<u64> = ev3.iter().map(|(i, _)| *i).collect();
    anyhow::ensure!(ids3 == (0u64..12).collect::<Vec<u64>>(), "replay event ids: {ids3:?}");
    if full_text.is_ascii() {
        let replay: String = ev3.iter().map(|(_, t)| t.as_str()).collect::<String>() + &tail3;
        anyhow::ensure!(replay == full_text, "full replay {replay:?} != live {full_text:?}");
    }

    // Graceful drain releases the serve loop (and writes the final
    // checkpoint on the way out).
    let mut s = TcpStream::connect(ADDR)?;
    write!(
        s,
        "POST /admin/drain HTTP/1.1\r\nHost: x\r\nContent-Length: 0\r\nConnection: close\r\n\r\n"
    )?;
    let mut out = String::new();
    s.read_to_string(&mut out)?;
    anyhow::ensure!(out.starts_with("HTTP/1.1 200"), "drain: {out}");
    Ok(())
}

#[test]
fn sse_resume_replays_without_gaps_or_duplicates() {
    let Some(rt) = runtime() else { return };
    let dir = tmp_dir("sse");
    let mut cfg = ServingConfig::default();
    cfg.policy = PolicyKind::Radar;
    cfg.journal_dir = dir.to_string_lossy().into_owned();
    cfg.journal_fsync_every = 1;
    let e = Engine::new(rt, cfg).unwrap();
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let client = std::thread::spawn(move || {
        let res = std::panic::catch_unwind(resume_driver);
        stop2.store(true, Ordering::Relaxed); // always release the server
        match res {
            Ok(r) => r,
            Err(_) => Err(anyhow::anyhow!("driver panicked")),
        }
    });
    radar_serve::server::serve(e, ADDR, stop).unwrap();
    client.join().unwrap().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}
