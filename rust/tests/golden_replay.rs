//! Integration: execute the compiled artifacts on the exact inputs
//! python used when writing `golden.npz`, and assert the outputs match
//! the python (jax/pallas) results — the cross-language correctness
//! contract for the whole AOT path.
//!
//! Requires `make artifacts` to have run (skips cleanly otherwise).

use radar_serve::config::ArtifactPaths;
use radar_serve::runtime::Runtime;
use std::collections::HashMap;
use xla::{FromRawBytes, Literal};

fn load_golden(paths: &ArtifactPaths) -> Option<HashMap<String, (Vec<usize>, Vec<f32>, Vec<i32>)>> {
    let npz = Literal::read_npz(paths.golden(), &()).ok()?;
    let mut out = HashMap::new();
    for (name, lit) in npz {
        let name = name.trim_end_matches(".npy").to_string();
        let shape: Vec<usize> = lit
            .array_shape()
            .ok()?
            .dims()
            .iter()
            .map(|d| *d as usize)
            .collect();
        match lit.ty().ok()? {
            xla::ElementType::F32 => {
                out.insert(name, (shape, lit.to_vec::<f32>().ok()?, vec![]));
            }
            xla::ElementType::S32 => {
                out.insert(name, (shape, vec![], lit.to_vec::<i32>().ok()?));
            }
            xla::ElementType::S64 => {
                let v64 = lit.to_vec::<i64>().ok()?;
                out.insert(name, (shape, vec![], v64.iter().map(|&x| x as i32).collect()));
            }
            _ => {}
        }
    }
    Some(out)
}

fn setup() -> Option<(Runtime, HashMap<String, (Vec<usize>, Vec<f32>, Vec<i32>)>)> {
    let paths = ArtifactPaths::new("artifacts", "sm");
    if !paths.manifest().exists() {
        eprintln!("skipping golden tests: run `make artifacts` first");
        return None;
    }
    let golden = load_golden(&paths)?;
    let rt = Runtime::load(paths).ok()?;
    Some((rt, golden))
}

fn assert_close(name: &str, got: &[f32], want: &[f32], tol: f32) {
    assert_eq!(got.len(), want.len(), "{name}: length");
    let mut max_diff = 0.0f32;
    for (g, w) in got.iter().zip(want) {
        max_diff = max_diff.max((g - w).abs());
    }
    assert!(
        max_diff <= tol,
        "{name}: max |diff| = {max_diff} > {tol}"
    );
}

/// Relative tolerance against the tensor's own scale — for exp()-based
/// outputs (phi features) whose magnitude tracks the trained key norms.
fn assert_close_rel(name: &str, got: &[f32], want: &[f32], rel: f32) {
    assert_eq!(got.len(), want.len(), "{name}: length");
    let scale = want.iter().fold(0.0f32, |m, w| m.max(w.abs())).max(1e-6);
    let mut max_diff = 0.0f32;
    for (g, w) in got.iter().zip(want) {
        max_diff = max_diff.max((g - w).abs());
    }
    assert!(
        max_diff <= rel * scale,
        "{name}: max |diff| = {max_diff} > {rel} * scale {scale}"
    );
}

#[test]
fn decode_artifact_matches_python() {
    let Some((rt, g)) = setup() else { return };
    let meta = rt.registry.resolve_decode(1, 128, 128).unwrap().clone();
    assert_eq!(meta.len, 128, "golden was generated for the S=128 bucket");
    let omega = rt.omega(128).unwrap();
    let out = rt
        .decode(
            &meta,
            &omega,
            &g["dec_tokens"].2,
            &g["dec_pos"].2,
            &g["dec_K"].1,
            &g["dec_V"].1,
            &g["dec_mask"].1,
        )
        .unwrap();
    assert_close("logits", &out.logits, &g["dec_out_logits"].1, 2e-3);
    assert_close("k_new", &out.k_new, &g["dec_out_k_new"].1, 1e-4);
    assert_close("v_new", &out.v_new, &g["dec_out_v_new"].1, 1e-4);
    assert_close_rel("feat_new", &out.feat_new, &g["dec_out_feat_new"].1, 1e-3);
    assert_close("probs", &out.probs, &g["dec_out_probs"].1, 1e-4);
}

#[test]
fn prefill_artifact_matches_python() {
    let Some((rt, g)) = setup() else { return };
    let meta = rt.registry.resolve_prefill(256, 128).unwrap().clone();
    assert_eq!(meta.len, 256);
    let omega = rt.omega(128).unwrap();
    let pos0 = g["pre_pos0"].2[0];
    let out = rt
        .prefill(
            &meta,
            &omega,
            &g["pre_tokens"].2,
            pos0,
            &g["pre_K"].1,
            &g["pre_V"].1,
            &g["pre_mask"].1,
        )
        .unwrap();
    assert_close("logits", &out.logits, &g["pre_out_logits"].1, 2e-3);
    assert_close("k_c", &out.k_c, &g["pre_out_k_c"].1, 1e-4);
    assert_close("v_c", &out.v_c, &g["pre_out_v_c"].1, 1e-4);
    assert_close_rel("feat_c", &out.feat_c, &g["pre_out_feat_c"].1, 1e-3);
    assert_close("colsum", &out.colsum, &g["pre_out_colsum"].1, 1e-3);
}

#[test]
fn per_layer_pipeline_matches_python() {
    let Some((rt, g)) = setup() else { return };
    let qkv_meta = rt.registry.resolve_qkv(1, 128).unwrap().clone();
    let omega = rt.omega(128).unwrap();
    let q_out = rt
        .qkv(&qkv_meta, 0, &omega, &g["lay_x"].1, &g["lay_pos"].2)
        .unwrap();
    assert_close("q", &q_out.q, &g["lay_out_q"].1, 1e-4);
    assert_close("k", &q_out.k, &g["lay_out_k"].1, 1e-4);
    assert_close("v", &q_out.v, &g["lay_out_v"].1, 1e-4);
    assert_close_rel("phi_q", &q_out.phi_q, &g["lay_out_phi_q"].1, 1e-3);
    assert_close_rel("phi_k", &q_out.phi_k, &g["lay_out_phi_k"].1, 1e-3);

    let am_meta = rt.registry.resolve_attn_mlp(1, 128).unwrap().clone();
    assert_eq!(am_meta.len, 128);
    // golden dec_mask is [1, L, H, S]; the attn_mlp golden used layer 0
    // slice [1, H, S].
    let mask_full = &g["dec_mask"].1;
    let (h, s) = (rt.config.n_heads, 128);
    let mask_l0 = &mask_full[..h * s];
    let out = rt
        .attn_mlp(
            &am_meta,
            0,
            &g["lay_x"].1,
            &q_out.q,
            &q_out.k,
            &q_out.v,
            &g["lay_K"].1,
            &g["lay_V"].1,
            mask_l0,
        )
        .unwrap();
    assert_close("x_out", &out.x, &g["lay_out_x"].1, 1e-3);
    assert_close("probs", &out.probs, &g["lay_out_probs"].1, 1e-4);
}

#[test]
fn host_embed_and_head_match_python() {
    let Some((rt, g)) = setup() else { return };
    let x = radar_serve::model::embed(&rt, &[5, 250]);
    assert_close("embed", &x, &g["emb_out"].1, 1e-6);
    let logits = radar_serve::model::head(&rt, &rt.config, &g["head_x"].1);
    assert_close("head", &logits, &g["head_out_logits"].1, 2e-3);
}
