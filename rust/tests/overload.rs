//! Overload-control & graceful-degradation suite (ISSUE acceptance
//! criteria):
//!   (a) above the watermark, a high-priority arrival displaces the
//!       lowest-priority queued entry ("shed:"), never an equal class,
//!   (b) a draining engine finishes in-flight work byte-identically
//!       while rejecting new submissions,
//!   (c) a watchdog trip force-finishes the offender and frees every
//!       KV block it held,
//!   (d) a NaN-poisoned Radar index falls back to exact attention for
//!       the step — the victim finishes with finite logprobs and its
//!       co-batched survivors stay byte-identical to a fault-free run,
//!   (e) an anomaly burst flips the circuit breaker into exact-attention
//!       degraded mode and recovers after the cool-down.
//!
//! The chaos sweep reads `FAULT_SEEDS` (';'-separated entries, each a
//! fault spec like `nan@3:2,stall@4x60` or a bare numeric seed).

use radar_serve::config::{ArtifactPaths, PolicyKind, ServingConfig};
use radar_serve::engine::{
    shed_victim, CircuitBreaker, Engine, FinishReason, GenRequest, HealthState, Priority,
    SessionResult, SubmitError, TokenBucket,
};
use radar_serve::faults::FaultPlan;
use radar_serve::model::tokenizer;
use radar_serve::runtime::Runtime;
use std::sync::Arc;
use std::time::Instant;

fn runtime() -> Option<Arc<Runtime>> {
    let paths = ArtifactPaths::new("artifacts", "sm");
    if !paths.manifest().exists() {
        eprintln!("skipping overload tests: run `make artifacts` first");
        return None;
    }
    Some(Arc::new(Runtime::load(paths).unwrap()))
}

/// Suppress the default panic report for *injected* panics only (bare
/// numeric FAULT_SEEDS entries script step panics); real test failures
/// keep the standard output. Installed once per process.
fn quiet_injected_panics() {
    use std::sync::Once;
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        let default = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let injected = info
                .payload()
                .downcast_ref::<&str>()
                .map(|s| s.contains("injected"))
                .or_else(|| {
                    info.payload().downcast_ref::<String>().map(|s| s.contains("injected"))
                })
                .unwrap_or(false);
            if !injected {
                default(info);
            }
        }));
    });
}

fn engine_with(
    rt: Arc<Runtime>,
    policy: PolicyKind,
    tweak: impl FnOnce(&mut ServingConfig),
) -> Engine {
    let mut cfg = ServingConfig::default();
    cfg.policy = policy;
    cfg.window = 32;
    cfg.budget = 64;
    tweak(&mut cfg);
    Engine::new(rt, cfg).unwrap()
}

/// Step until idle, bounded so a scheduling bug fails loudly instead
/// of hanging the suite.
fn drive(e: &mut Engine, max_steps: usize) {
    let mut n = 0;
    while !e.idle() {
        e.step().unwrap();
        n += 1;
        assert!(n < max_steps, "engine did not go idle within {max_steps} steps");
    }
}

const PROMPTS: [&str; 3] = ["the stream carries ", "old light towards ", "quiet hills answer "];

fn run_trio(e: &mut Engine, max_new: usize) -> Vec<SessionResult> {
    let handles: Vec<_> = PROMPTS
        .iter()
        .map(|p| e.submit(GenRequest::new(tokenizer::encode(p), max_new)).unwrap())
        .collect();
    drive(e, 500);
    handles.iter().map(|h| h.collect()).collect()
}

fn req_with_priority(prompt: &str, max_new: usize, priority: Priority) -> GenRequest {
    let mut r = GenRequest::new(tokenizer::encode(prompt), max_new);
    r.priority = priority;
    r
}

// ---------------------------------------------------------------------
// Pure tests — no artifacts required, run everywhere
// ---------------------------------------------------------------------

#[test]
fn overload_primitives_compose_through_the_public_api() {
    // The crate surface re-exports the whole overload layer; exercise
    // each piece the way the engine composes them.
    let mut bucket = TokenBucket::new(100.0, 10.0);
    let t0 = Instant::now();
    assert!(bucket.try_take(10.0, t0).is_ok());
    assert!(bucket.try_take(1.0, t0).is_err(), "drained bucket must reject");

    let q = [(1, Priority::Batch), (2, Priority::Normal)];
    assert_eq!(shed_victim(q.iter().copied(), Priority::High), Some(1));
    assert_eq!(shed_victim(q.iter().copied(), Priority::Batch), None);

    let mut cb = CircuitBreaker::new(1, 4, 4);
    cb.record(3);
    assert!(cb.tick(4).is_some(), "threshold 1 must flip on one event");
    assert!(cb.degraded());

    let h = HealthState::new();
    assert!(h.ready());
    h.begin_drain();
    assert!(!h.ready());
}

#[test]
fn nan_and_stall_specs_parse_from_the_fault_grammar() {
    let plan = FaultPlan::parse("nan@3:2,stall@4x60").unwrap();
    let same = FaultPlan::parse("stall@4x60,nan@3:2").unwrap();
    assert_eq!(plan, same, "spec order must not matter");
    assert!(FaultPlan::parse("nan@").is_err());
    assert!(FaultPlan::parse("stall@4").is_err(), "stall needs a duration");
}

// ---------------------------------------------------------------------
// Engine integration — artifact-gated
// ---------------------------------------------------------------------

#[test]
fn shed_displaces_lowest_priority_first_never_equal() {
    let Some(rt) = runtime() else { return };
    let mut e = engine_with(rt, PolicyKind::Radar, |c| {
        c.prefix_cache = false;
        c.max_pending = 2;
        c.shed_watermark_pct = 100; // hot exactly when the queue is full
    });
    let batch = e.submit(req_with_priority(PROMPTS[0], 4, Priority::Batch)).unwrap();
    let normal = e.submit(req_with_priority(PROMPTS[1], 4, Priority::Normal)).unwrap();
    // Queue full (2/2): a high arrival displaces the batch entry.
    let high = e.submit(req_with_priority(PROMPTS[2], 4, Priority::High)).unwrap();
    let shed = batch.collect();
    let msg = shed.error.as_deref().expect("batch entry must be shed");
    assert!(msg.starts_with("shed:"), "503-style prefix expected, got: {msg}");
    assert!(shed.tokens.is_empty(), "shed before admission, so no tokens");
    assert_eq!(e.metrics.counter("shed_requests"), 1);
    // Queue full again with {normal, high}: another normal arrival has
    // no strictly-lower victim and falls through to the hard cap.
    match e.submit(req_with_priority(PROMPTS[0], 4, Priority::Normal)) {
        Err(SubmitError::QueueFull { depth }) => assert_eq!(depth, 2),
        other => panic!("expected QueueFull, got {:?}", other.map(|h| h.id)),
    }
    assert_eq!(e.metrics.counter("shed_requests"), 1, "equal class must not shed");
    // The survivors run to completion untouched.
    drive(&mut e, 500);
    for (name, h) in [("normal", normal), ("high", high)] {
        let out = h.collect();
        assert!(out.error.is_none(), "{name} failed: {:?}", out.error);
        assert_eq!(out.tokens.len(), 4, "{name} did not finish");
    }
    assert_eq!(e.pool.used_blocks(), 0);
}

#[test]
fn admission_bucket_rejects_with_retry_after() {
    let Some(rt) = runtime() else { return };
    let mut e = engine_with(rt, PolicyKind::Radar, |c| {
        c.prefix_cache = false;
        c.admit_rate = 1.0; // 1 cost unit/s: one request drains the bucket
        c.admit_burst = 8.0;
    });
    let h = e.submit(GenRequest::new(tokenizer::encode(PROMPTS[0]), 4)).unwrap();
    match e.submit(GenRequest::new(tokenizer::encode(PROMPTS[1]), 4)) {
        Err(SubmitError::RateLimited { retry_after_ms }) => {
            assert!(retry_after_ms > 0, "retry hint must be positive");
        }
        other => panic!("expected RateLimited, got {:?}", other.map(|h| h.id)),
    }
    assert_eq!(e.metrics.counter("requests_rejected"), 1);
    // The admitted request is unaffected by the gate.
    drive(&mut e, 500);
    let out = h.collect();
    assert!(out.error.is_none(), "admitted request failed: {:?}", out.error);
    assert_eq!(out.tokens.len(), 4);
}

#[test]
fn drain_finishes_inflight_byte_identically_and_rejects_new_work() {
    let Some(rt) = runtime() else { return };
    let mut base = engine_with(rt.clone(), PolicyKind::Radar, |c| c.prefix_cache = false);
    let baseline = run_trio(&mut base, 6);
    assert!(baseline.iter().all(|r| r.error.is_none()));

    let mut e = engine_with(rt, PolicyKind::Radar, |c| c.prefix_cache = false);
    let handles: Vec<_> = PROMPTS
        .iter()
        .map(|p| e.submit(GenRequest::new(tokenizer::encode(p), 6)).unwrap())
        .collect();
    e.step().unwrap(); // all three admitted and mid-decode
    e.health.begin_drain();
    assert!(!e.health.ready(), "draining must drop readiness");
    match e.submit(GenRequest::new(tokenizer::encode(PROMPTS[0]), 4)) {
        Err(SubmitError::Draining) => {}
        other => panic!("expected Draining, got {:?}", other.map(|h| h.id)),
    }
    drive(&mut e, 500);
    for (i, h) in handles.iter().enumerate() {
        let out = h.collect();
        assert!(out.error.is_none(), "in-flight seq {} failed: {:?}", i + 1, out.error);
        assert_eq!(out.finish, Some(FinishReason::Length), "seq {}", i + 1);
        assert_eq!(out.tokens, baseline[i].tokens, "drain changed seq {}'s output", i + 1);
    }
    assert_eq!(e.pool.used_blocks(), 0, "drained engine must hold no blocks");
}

#[test]
fn watchdog_force_finishes_radar_staller_and_frees_blocks() {
    let Some(rt) = runtime() else { return };
    let mut e = engine_with(rt, PolicyKind::Radar, |c| {
        c.prefix_cache = false;
        c.watchdog_ms = 25;
        c.faults = Some(FaultPlan::parse("stall@3x80").unwrap());
    });
    let a = e.submit(GenRequest::new(tokenizer::encode(PROMPTS[0]), 6)).unwrap();
    let b = e.submit(GenRequest::new(tokenizer::encode(PROMPTS[1]), 6)).unwrap();
    drive(&mut e, 500);
    let (a, b) = (a.collect(), b.collect());
    // The stall is owned by the first sequence queried at the armed
    // step; exactly one of the two must be force-finished.
    let (victim, survivor) = if a.error.is_some() { (&a, &b) } else { (&b, &a) };
    let msg = victim.error.as_deref().expect("one sequence must trip the watchdog");
    assert!(msg.contains("watchdog:"), "unexpected error: {msg}");
    assert!(survivor.error.is_none(), "survivor failed: {:?}", survivor.error);
    assert_eq!(survivor.tokens.len(), 6);
    assert_eq!(e.metrics.counter("watchdog_trips"), 1);
    assert_eq!(e.metrics.counter("injected_stalls"), 1);
    assert_eq!(e.pool.used_blocks(), 0, "force-finish must free the victim's blocks");
    assert!(!e.health.ready(), "readiness stays off until the quiet window passes");
}

#[test]
fn watchdog_covers_the_fused_staging_path_too() {
    let Some(rt) = runtime() else { return };
    let mut e = engine_with(rt, PolicyKind::Streaming, |c| {
        c.prefix_cache = false;
        c.watchdog_ms = 25;
        c.faults = Some(FaultPlan::parse("stall@2x80").unwrap());
    });
    let out = run_trio(&mut e, 6);
    let trips: Vec<_> = out
        .iter()
        .filter(|r| r.error.as_deref().is_some_and(|m| m.contains("watchdog:")))
        .collect();
    assert_eq!(trips.len(), 1, "exactly one fused row must be force-finished");
    assert_eq!(out.iter().filter(|r| r.error.is_none()).count(), 2);
    assert_eq!(e.metrics.counter("watchdog_trips"), 1);
    assert_eq!(e.pool.used_blocks(), 0);
}

#[test]
fn nan_poison_falls_back_finite_while_survivors_match_baseline() {
    let Some(rt) = runtime() else { return };
    let mut base = engine_with(rt.clone(), PolicyKind::Radar, |c| c.prefix_cache = false);
    let baseline = run_trio(&mut base, 6);
    assert!(baseline.iter().all(|r| r.error.is_none()));

    let mut e = engine_with(rt, PolicyKind::Radar, |c| {
        c.prefix_cache = false;
        c.faults = Some(FaultPlan::parse("nan@3:2").unwrap());
    });
    let out = run_trio(&mut e, 6);
    // The fallback is transparent: every sequence — the poisoned one
    // included — runs to a normal finish with finite logprobs.
    for (i, r) in out.iter().enumerate() {
        assert!(r.error.is_none(), "seq {} failed: {:?}", i + 1, r.error);
        assert_eq!(r.finish, Some(FinishReason::Length), "seq {}", i + 1);
        assert_eq!(r.tokens.len(), 6, "seq {} cut short", i + 1);
        assert!(
            r.logprobs.iter().all(|lp| lp.is_finite()),
            "seq {} leaked a non-finite logprob: {:?}",
            i + 1,
            r.logprobs
        );
    }
    // Co-batched survivors are byte-identical to the fault-free run.
    // (The victim's step ran exact attention instead of top-k segments,
    // so its continuation is finite but not contractually identical.)
    for i in [0, 2] {
        assert_eq!(out[i].tokens, baseline[i].tokens, "survivor {} diverged", i + 1);
        assert_eq!(out[i].logprobs, baseline[i].logprobs, "survivor {} logprobs", i + 1);
    }
    assert_eq!(e.metrics.counter("injected_nans"), 1);
    assert!(e.metrics.counter("anomaly_fallbacks") >= 1, "anomaly must be detected");
    assert!(e.metrics.counter("anomalous_planes") >= 1);
    assert_eq!(e.metrics.counter("contained_errors"), 0, "fallback is not an error");
    assert_eq!(e.pool.used_blocks(), 0);
}

#[test]
fn anomaly_burst_flips_breaker_then_recovers_after_cooldown() {
    let Some(rt) = runtime() else { return };
    let mut e = engine_with(rt, PolicyKind::Radar, |c| {
        c.prefix_cache = false;
        c.breaker_threshold = 1; // one anomaly flips the engine
        c.breaker_window = 4;
        c.breaker_cooldown = 4;
        c.faults = Some(FaultPlan::parse("nan@3:1").unwrap());
    });
    // 20 decode steps: the anomaly lands at step 3, the breaker enters
    // degraded mode on the next tick and exits after the cool-down,
    // all well before the sequence finishes.
    let h = e.submit(GenRequest::new(tokenizer::encode(PROMPTS[0]), 20)).unwrap();
    drive(&mut e, 500);
    let out = h.collect();
    assert!(out.error.is_none(), "victim failed: {:?}", out.error);
    assert_eq!(out.tokens.len(), 20);
    assert!(out.logprobs.iter().all(|lp| lp.is_finite()));
    assert_eq!(e.metrics.counter("degraded_mode_entered"), 1);
    assert_eq!(e.metrics.counter("degraded_mode_exited"), 1);
    assert!(!e.degraded(), "breaker must recover after the cool-down");
    assert_eq!(e.pool.used_blocks(), 0);
}

#[test]
fn overload_chaos_sweep_terminates_cleanly() {
    let Some(rt) = runtime() else { return };
    quiet_injected_panics();
    // Entries are ';'-separated: either a fault spec (may contain ',')
    // or a bare numeric seed for the legacy randomized plan.
    let specs = std::env::var("FAULT_SEEDS")
        .unwrap_or_else(|_| "nan@3:2;stall@3x60;nan@4,stall@5x60".into());
    for entry in specs.split(';').map(str::trim).filter(|s| !s.is_empty()) {
        let plan = match entry.parse::<u64>() {
            Ok(seed) => FaultPlan::seeded(seed, 12, 4),
            Err(_) => FaultPlan::parse(entry)
                .unwrap_or_else(|e| panic!("bad FAULT_SEEDS entry {entry:?}: {e}")),
        };
        let mut e = engine_with(rt.clone(), PolicyKind::Radar, |c| {
            c.prefix_cache = false;
            c.watchdog_ms = 30;
            c.faults = Some(plan);
        });
        let out = run_trio(&mut e, 6);
        for (j, r) in out.iter().enumerate() {
            assert!(
                r.finish.is_some() || r.error.is_some(),
                "spec {entry:?} seq {} got no terminal event",
                j + 1
            );
            // Whatever was delivered must be finite (sanitizer backstop).
            assert!(
                r.logprobs.iter().all(|lp| lp.is_finite()),
                "spec {entry:?} seq {} delivered a non-finite logprob",
                j + 1
            );
        }
        assert!(e.idle(), "spec {entry:?}: engine stuck");
        assert_eq!(e.pool.used_blocks(), 0, "spec {entry:?}: kv blocks leaked");
    }
}
