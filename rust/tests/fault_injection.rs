//! Chaos suite: deterministic fault injection against the engine.
//!
//! The invariants under test (ISSUE acceptance criteria):
//!   (a) an injected fault finishes only the victim sequence,
//!   (b) every other concurrent session is byte-identical to a
//!       fault-free run,
//!   (c) the block pool drains back to its pre-run level (no leaks,
//!       no refcount underflows),
//!   (d) a preempted-then-requeued request still completes, with the
//!       `preemptions` metric incremented.
//!
//! Seeds for the randomized sweep come from `FAULT_SEEDS` (CI runs a
//! matrix over several triples).

use radar_serve::config::{ArtifactPaths, PolicyKind, ServingConfig};
use radar_serve::engine::{Engine, FinishReason, GenRequest, SessionResult};
use radar_serve::faults::FaultPlan;
use radar_serve::model::tokenizer;
use radar_serve::runtime::Runtime;
use std::sync::Arc;

fn runtime() -> Option<Arc<Runtime>> {
    let paths = ArtifactPaths::new("artifacts", "sm");
    if !paths.manifest().exists() {
        eprintln!("skipping fault-injection tests: run `make artifacts` first");
        return None;
    }
    Some(Arc::new(Runtime::load(paths).unwrap()))
}

/// Suppress the default panic report for *injected* panics only; real
/// test failures keep the standard output. Installed once per process.
fn quiet_injected_panics() {
    use std::sync::Once;
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        let default = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let injected = info
                .payload()
                .downcast_ref::<&str>()
                .map(|s| s.contains("injected"))
                .or_else(|| {
                    info.payload().downcast_ref::<String>().map(|s| s.contains("injected"))
                })
                .unwrap_or(false);
            if !injected {
                default(info);
            }
        }));
    });
}

fn engine_with(
    rt: Arc<Runtime>,
    policy: PolicyKind,
    tweak: impl FnOnce(&mut ServingConfig),
) -> Engine {
    let mut cfg = ServingConfig::default();
    cfg.policy = policy;
    cfg.window = 32;
    cfg.budget = 64;
    tweak(&mut cfg);
    Engine::new(rt, cfg).unwrap()
}

/// Step until idle, bounded so a scheduling bug fails loudly instead
/// of hanging the suite.
fn drive(e: &mut Engine, max_steps: usize) {
    let mut n = 0;
    while !e.idle() {
        e.step().unwrap();
        n += 1;
        assert!(n < max_steps, "engine did not go idle within {max_steps} steps");
    }
}

const PROMPTS: [&str; 3] = ["the stream carries ", "old light towards ", "quiet hills answer "];

/// Submit the three standard prompts, run to idle, return each
/// session's result in submit order (ids 1, 2, 3).
fn run_trio(e: &mut Engine, max_new: usize) -> Vec<SessionResult> {
    let handles: Vec<_> = PROMPTS
        .iter()
        .map(|p| e.submit(GenRequest::new(tokenizer::encode(p), max_new)).unwrap())
        .collect();
    drive(e, 500);
    handles.iter().map(|h| h.collect()).collect()
}

#[test]
fn plans_are_deterministic_without_artifacts() {
    // Pure planning layer: no runtime needed, runs everywhere.
    let a = FaultPlan::seeded(42, 20, 5);
    let b = FaultPlan::seeded(42, 20, 5);
    assert_eq!(a, b, "same seed must script the same faults");
    let c = FaultPlan::seeded(43, 20, 5);
    assert_ne!(a, c, "different seeds must diverge");
    let parsed = FaultPlan::parse("seeded:42:20:5").unwrap();
    assert_eq!(a, parsed, "spec form must match the constructor");
}

#[test]
fn fused_panic_is_contained_and_survivors_match_baseline() {
    let Some(rt) = runtime() else { return };
    quiet_injected_panics();
    // Prefix cache off so "pool drains to zero" is exact.
    let mut base = engine_with(rt.clone(), PolicyKind::Streaming, |c| c.prefix_cache = false);
    let baseline = run_trio(&mut base, 6);
    assert!(baseline.iter().all(|r| r.error.is_none()));

    let mut e = engine_with(rt, PolicyKind::Streaming, |c| {
        c.prefix_cache = false;
        c.faults = Some(FaultPlan::parse("panic@2:3").unwrap());
    });
    let out = run_trio(&mut e, 6);

    // (a) only the victim fails, with the panic surfaced as an error.
    let victim = &out[2];
    let msg = victim.error.as_deref().expect("victim must receive an error event");
    assert!(msg.contains("panicked"), "unexpected error: {msg}");
    assert!(victim.tokens.len() < 6, "victim must not finish normally");
    // (b) the other rows of the same fused batch are untouched.
    for i in [0, 1] {
        assert!(out[i].error.is_none(), "survivor {i} failed: {:?}", out[i].error);
        assert_eq!(out[i].finish, Some(FinishReason::Length));
        assert_eq!(out[i].tokens, baseline[i].tokens, "survivor {i} diverged from baseline");
    }
    // (c) all blocks returned, (d) accounting.
    assert_eq!(e.pool.used_blocks(), 0, "kv blocks leaked past containment");
    assert_eq!(e.metrics.counter("contained_errors"), 1);
    assert_eq!(e.metrics.counter("requests_failed"), 1);
    assert_eq!(e.metrics.counter("requests_completed"), 2);
}

#[test]
fn radar_panic_is_contained_per_sequence() {
    let Some(rt) = runtime() else { return };
    quiet_injected_panics();
    let mut e = engine_with(rt, PolicyKind::Radar, |c| {
        c.prefix_cache = false;
        c.faults = Some(FaultPlan::parse("panic@2:2").unwrap());
    });
    let a = e.submit(GenRequest::new(tokenizer::encode(PROMPTS[0]), 6)).unwrap();
    let b = e.submit(GenRequest::new(tokenizer::encode(PROMPTS[1]), 6)).unwrap();
    drive(&mut e, 500);
    let (a, b) = (a.collect(), b.collect());
    assert!(a.error.is_none(), "survivor failed: {:?}", a.error);
    assert_eq!(a.tokens.len(), 6);
    assert!(b.error.as_deref().is_some_and(|m| m.contains("panicked")));
    assert_eq!(e.pool.used_blocks(), 0);
    assert_eq!(e.metrics.counter("contained_errors"), 1);
}

#[test]
fn kv_pressure_preempts_victim_which_recovers_byte_identically() {
    let Some(rt) = runtime() else { return };
    let mut base = engine_with(rt.clone(), PolicyKind::Streaming, |_| {});
    let baseline = run_trio(&mut base, 6);

    // An injected allocation failure on seq 3 mid-decode: it is the
    // tie-broken victim (least progress, youngest), gets preempted,
    // re-prefills warm through the prefix cache, and resumes off its
    // preserved sampler to full completion.
    let mut e = engine_with(rt, PolicyKind::Streaming, |c| {
        c.faults = Some(FaultPlan::parse("alloc@3:3").unwrap());
    });
    let out = run_trio(&mut e, 6);
    for (i, r) in out.iter().enumerate() {
        assert!(r.error.is_none(), "seq {} failed: {:?}", i + 1, r.error);
        assert_eq!(r.finish, Some(FinishReason::Length), "seq {}", i + 1);
        assert_eq!(r.tokens.len(), 6, "seq {} did not run to completion", i + 1);
    }
    // Unpreempted sessions are byte-identical to the fault-free run.
    // (The victim's replay is numerically equivalent but rebuilds its
    // generated-token KV through the prefill kernel, so its low bits
    // are not contractually identical.)
    for i in [0, 1] {
        assert_eq!(out[i].tokens, baseline[i].tokens, "seq {} diverged", i + 1);
    }
    assert_eq!(e.metrics.counter("preemptions"), 1);
    assert_eq!(e.metrics.latency_count("preempt_recovery"), 1, "recovery latency not recorded");
    assert_eq!(e.metrics.counter("contained_errors"), 0, "preemption is not an error");
    assert_eq!(e.pool.used_blocks(), e.prefix.cached_blocks(), "non-prefix blocks leaked");
}

#[test]
fn preemption_budget_exhaustion_fails_with_capacity_error() {
    let Some(rt) = runtime() else { return };
    let mut e = engine_with(rt, PolicyKind::Streaming, |c| {
        c.prefix_cache = false;
        c.max_preemptions = 0;
        c.faults = Some(FaultPlan::parse("alloc@2:1").unwrap());
    });
    let h = e.submit(GenRequest::new(tokenizer::encode(PROMPTS[0]), 6)).unwrap();
    drive(&mut e, 500);
    let out = h.collect();
    let msg = out.error.as_deref().expect("request over budget must fail");
    assert!(msg.starts_with("capacity:"), "503-style prefix expected, got: {msg}");
    assert_eq!(e.metrics.counter("preemptions"), 1);
    assert_eq!(e.pool.used_blocks(), 0);
}

#[test]
fn active_deadline_times_out_keeping_partial_tokens() {
    let Some(rt) = runtime() else { return };
    let mut e = engine_with(rt, PolicyKind::Streaming, |_| {});
    let mut req = GenRequest::new(tokenizer::encode(PROMPTS[0]), 256);
    req.timeout_ms = Some(40);
    let h = e.submit(req).unwrap();
    e.step().unwrap(); // admit + first decode, well inside the deadline
    std::thread::sleep(std::time::Duration::from_millis(60));
    drive(&mut e, 500);
    let out = h.collect();
    assert!(out.error.is_none(), "timeout is a finish reason, not an error");
    assert_eq!(out.finish, Some(FinishReason::Timeout));
    assert!(!out.tokens.is_empty(), "tokens produced before expiry must stand");
    assert!(out.tokens.len() < 256, "deadline did not interrupt generation");
    assert_eq!(e.metrics.counter("timeouts"), 1);
    assert_eq!(e.pool.used_blocks(), e.prefix.cached_blocks());
}

#[test]
fn queue_wait_deadline_expires_parked_requests() {
    let Some(rt) = runtime() else { return };
    let mut e = engine_with(rt, PolicyKind::Streaming, |c| {
        c.max_batch = 1;
        c.queue_timeout_ms = 30;
    });
    let a = e.submit(GenRequest::new(tokenizer::encode(PROMPTS[0]), 64)).unwrap();
    let b = e.submit(GenRequest::new(tokenizer::encode(PROMPTS[1]), 4)).unwrap();
    e.step().unwrap(); // A takes the only slot; B parks in the queue
    std::thread::sleep(std::time::Duration::from_millis(50));
    e.step().unwrap(); // queue sweep expires B
    let out_b = b.collect();
    assert_eq!(out_b.finish, Some(FinishReason::Timeout));
    assert!(out_b.tokens.is_empty(), "B never ran, so no tokens");
    assert_eq!(e.metrics.counter("timeouts"), 1);
    a.cancel();
    drive(&mut e, 500);
    let out_a = a.collect();
    assert!(out_a.finish.is_some() || out_a.error.is_some(), "A must still terminate");
}

#[test]
fn fail_all_drains_queue_sessions_and_reclaims_all_blocks() {
    let Some(rt) = runtime() else { return };
    let mut e = engine_with(rt, PolicyKind::Streaming, |c| c.max_batch = 1);
    let handles: Vec<_> = PROMPTS
        .iter()
        .map(|p| e.submit(GenRequest::new(tokenizer::encode(p), 8)).unwrap())
        .collect();
    e.step().unwrap(); // one admitted (holding blocks), two still queued
    assert!(e.pool.used_blocks() > 0);
    assert_eq!(e.queue_depth(), 2);
    e.fail_all("engine error: test shutdown");
    for (i, h) in handles.iter().enumerate() {
        let out = h.collect();
        let msg = out.error.as_deref().unwrap_or_else(|| panic!("session {i} not failed"));
        assert!(msg.contains("test shutdown"), "session {i}: {msg}");
    }
    assert_eq!(e.pool.used_blocks(), 0, "fail_all must release every block");
    assert_eq!(e.prefix.cached_blocks(), 0, "prefix retention survives shutdown");
    assert!(e.idle());
    // The engine object itself stays serviceable afterwards.
    let h = e.submit(GenRequest::new(tokenizer::encode(PROMPTS[2]), 4)).unwrap();
    drive(&mut e, 500);
    let out = h.collect();
    assert!(out.error.is_none(), "fresh request after fail_all: {:?}", out.error);
    assert_eq!(out.tokens.len(), 4);
}

#[test]
fn seeded_chaos_sweep_terminates_cleanly() {
    let Some(rt) = runtime() else { return };
    quiet_injected_panics();
    let seeds = std::env::var("FAULT_SEEDS").unwrap_or_else(|_| "1,2,3".into());
    for (i, seed) in seeds.split(',').filter_map(|s| s.trim().parse::<u64>().ok()).enumerate() {
        // Alternate pipelines so both decode paths see every seed set.
        let policy = if i % 2 == 0 { PolicyKind::Streaming } else { PolicyKind::Radar };
        let mut e = engine_with(rt.clone(), policy, |c| {
            c.faults = Some(FaultPlan::seeded(seed, 12, 4));
        });
        let out = run_trio(&mut e, 6);
        for (j, r) in out.iter().enumerate() {
            assert!(
                r.finish.is_some() || r.error.is_some(),
                "seed {seed} seq {} got no terminal event",
                j + 1
            );
        }
        assert!(e.idle(), "seed {seed}: engine stuck");
        assert_eq!(
            e.pool.used_blocks(),
            e.prefix.cached_blocks(),
            "seed {seed}: kv blocks leaked"
        );
    }
}
