//! Session + /v1 HTTP surface integration (skipped without artifacts):
//! streaming equals non-streaming for the same seeded request, a
//! cancelled session frees its KV blocks within one engine step, the
//! bounded queue rejects with QueueFull, and a mid-stream client
//! disconnect is observed through the metrics/pool counters.

use radar_serve::config::{ArtifactPaths, PolicyKind, ServingConfig};
use radar_serve::engine::{Engine, FinishReason, GenRequest, SubmitError};
use radar_serve::model::tokenizer;
use radar_serve::runtime::Runtime;
use radar_serve::util::json::Json;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

fn runtime() -> Option<Arc<Runtime>> {
    let paths = ArtifactPaths::new("artifacts", "sm");
    if !paths.manifest().exists() {
        eprintln!("skipping server tests: run `make artifacts` first");
        return None;
    }
    Some(Arc::new(Runtime::load(paths).unwrap()))
}

fn engine(rt: Arc<Runtime>) -> Engine {
    let mut cfg = ServingConfig::default();
    cfg.policy = PolicyKind::Radar;
    Engine::new(rt, cfg).unwrap()
}

// ---------------------------------------------------------------------
// Engine-level session semantics (no sockets)
// ---------------------------------------------------------------------

#[test]
fn cancel_frees_blocks_within_one_step() {
    let Some(rt) = runtime() else { return };
    let mut e = engine(rt);
    let h = e
        .submit(GenRequest::new(tokenizer::encode("the stream carries old light "), 64))
        .unwrap();
    e.step().unwrap(); // admission + prefill + first token
    assert!(e.pool.used_blocks() > 0, "prefill should hold blocks");
    h.cancel();
    e.step().unwrap(); // the cancel sweep runs before any decode work
    assert_eq!(e.pool.used_blocks(), 0, "cancel must free blocks in one step");
    assert_eq!(e.metrics.counter("requests_cancelled"), 1);
    let out = h.collect();
    assert_eq!(out.finish, Some(FinishReason::Cancelled));
    assert!(out.tokens.len() < 64, "must not have run to completion");
}

#[test]
fn bounded_queue_rejects_when_full() {
    let Some(rt) = runtime() else { return };
    let mut cfg = ServingConfig::default();
    cfg.policy = PolicyKind::Radar;
    cfg.max_pending = 2;
    let mut e = Engine::new(rt, cfg).unwrap();
    let prompt = tokenizer::encode("quiet hills ");
    let h1 = e.submit(GenRequest::new(prompt.clone(), 4)).unwrap();
    let h2 = e.submit(GenRequest::new(prompt.clone(), 4)).unwrap();
    match e.submit(GenRequest::new(prompt.clone(), 4)) {
        Err(SubmitError::QueueFull { depth }) => assert_eq!(depth, 2),
        other => panic!("expected QueueFull, got {:?}", other.map(|h| h.id)),
    }
    assert_eq!(e.metrics.counter("requests_rejected"), 1);
    // Over-long requests are rejected up front, before any allocation.
    match e.submit(GenRequest::new(vec![1; 10], 8192)) {
        Err(SubmitError::TooLong { .. }) => {}
        other => panic!("expected TooLong, got {:?}", other.map(|h| h.id)),
    }
    // The queued sessions still run to completion and free everything.
    while !e.idle() {
        e.step().unwrap();
    }
    for h in [h1, h2] {
        let out = h.collect();
        assert!(out.error.is_none());
        assert_eq!(out.tokens.len(), 4);
        assert_eq!(out.finish, Some(FinishReason::Length));
    }
    assert_eq!(e.pool.used_blocks(), 0, "finished sessions must be reaped");
}

#[test]
fn session_stream_matches_legacy_blocking_path() {
    let Some(rt) = runtime() else { return };
    let prompt = "the stream carries old light towards dawn ";
    // Legacy add/run_to_completion.
    let mut e1 = engine(rt.clone());
    let id = e1.add(GenRequest::new(tokenizer::encode(prompt), 12)).unwrap();
    let results = e1.run_to_completion().unwrap();
    let legacy = results.into_iter().find(|r| r.id == id).unwrap();
    let legacy_tail = legacy.tokens[legacy.tokens.len() - 12..].to_vec();
    // Session stream (greedy default, same engine config).
    let mut e2 = engine(rt);
    let h = e2.submit(GenRequest::new(tokenizer::encode(prompt), 12)).unwrap();
    while !e2.idle() {
        e2.step().unwrap();
    }
    let out = h.collect();
    assert!(out.error.is_none());
    assert_eq!(out.tokens, legacy_tail, "session tokens must match blocking path");
    assert_eq!(out.logprobs.len(), 12);
    let usage = out.usage.unwrap();
    assert_eq!(usage.completion_tokens, 12);
    assert!(usage.prompt_tokens > 0);
}

// ---------------------------------------------------------------------
// HTTP surface (server on the test thread, client on a driver thread)
// ---------------------------------------------------------------------

const ADDR: &str = "127.0.0.1:18911";

fn read_response(reader: &mut BufReader<TcpStream>) -> anyhow::Result<(u16, String)> {
    let mut status_line = String::new();
    reader.read_line(&mut status_line)?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow::anyhow!("bad status line {status_line:?}"))?;
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        if h.trim().is_empty() {
            break;
        }
        if let Some((name, value)) = h.split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value.trim().parse().unwrap_or(0);
            }
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok((status, String::from_utf8_lossy(&body).into_owned()))
}

fn post_completions(writer: &mut TcpStream, body: &str) -> anyhow::Result<()> {
    write!(
        writer,
        "POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        body
    )?;
    Ok(())
}

fn http_get(path: &str) -> anyhow::Result<String> {
    let mut s = TcpStream::connect(ADDR)?;
    write!(s, "GET {path} HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n")?;
    let mut out = String::new();
    s.read_to_string(&mut out)?;
    Ok(out)
}

fn sse_text(raw: &str) -> (String, Option<String>) {
    let mut text = String::new();
    let mut finish = None;
    for line in raw.lines() {
        let Some(payload) = line.strip_prefix("data: ") else { continue };
        if payload == "[DONE]" {
            break;
        }
        let j = Json::parse(payload).unwrap();
        let choice = &j.get("choices").unwrap().as_arr().unwrap()[0];
        text.push_str(choice.get("text").and_then(Json::as_str).unwrap_or(""));
        if let Some(f) = choice.get("finish_reason").and_then(Json::as_str) {
            finish = Some(f.to_string());
        }
    }
    (text, finish)
}

fn driver() -> anyhow::Result<()> {
    for _ in 0..200 {
        if TcpStream::connect(ADDR).is_ok() {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
    }

    // Protocol edges: unknown method -> 405, oversized body -> 413,
    // wrong method on a known route -> 405, unknown route -> 404.
    {
        let mut s = TcpStream::connect(ADDR)?;
        write!(s, "BREW /health HTTP/1.1\r\n\r\n")?;
        let mut out = String::new();
        s.read_to_string(&mut out)?;
        anyhow::ensure!(out.starts_with("HTTP/1.1 405"), "BREW: {out}");
    }
    {
        let mut s = TcpStream::connect(ADDR)?;
        write!(s, "POST /v1/completions HTTP/1.1\r\nContent-Length: 99999999\r\n\r\n")?;
        let mut out = String::new();
        s.read_to_string(&mut out)?;
        anyhow::ensure!(out.starts_with("HTTP/1.1 413"), "oversized: {out}");
        anyhow::ensure!(out.contains("payload_too_large"), "oversized body: {out}");
    }
    {
        let mut s = TcpStream::connect(ADDR)?;
        write!(s, "GET /v1/completions HTTP/1.1\r\nConnection: close\r\n\r\n")?;
        let mut out = String::new();
        s.read_to_string(&mut out)?;
        anyhow::ensure!(out.starts_with("HTTP/1.1 405"), "GET completions: {out}");
    }
    {
        let resp = http_get("/nope")?;
        anyhow::ensure!(resp.starts_with("HTTP/1.1 404"), "404: {resp}");
    }
    // Validation: structured 400 with an error body.
    {
        let stream = TcpStream::connect(ADDR)?;
        let mut writer = stream.try_clone()?;
        let mut reader = BufReader::new(stream);
        post_completions(&mut writer, r#"{"max_tokens":4}"#)?;
        let (status, body) = read_response(&mut reader)?;
        anyhow::ensure!(status == 400, "missing prompt: {status} {body}");
        let j = Json::parse(&body)?;
        anyhow::ensure!(
            j.path("error.type").and_then(Json::as_str) == Some("invalid_request_error"),
            "error shape: {body}"
        );
        // A present-but-blank prompt is equally invalid: whitespace-only
        // input must not reach the tokenizer.
        post_completions(&mut writer, r#"{"prompt":"   \t\n","max_tokens":4}"#)?;
        let (status, body) = read_response(&mut reader)?;
        anyhow::ensure!(status == 400, "blank prompt: {status} {body}");
        anyhow::ensure!(body.contains("non-whitespace"), "blank prompt body: {body}");
        // Unknown priority class: structured 400, not a silent default.
        post_completions(&mut writer, r#"{"prompt":"hi","max_tokens":4,"priority":"urgent"}"#)?;
        let (status, body) = read_response(&mut reader)?;
        anyhow::ensure!(status == 400, "bad priority: {status} {body}");
        anyhow::ensure!(body.contains("priority"), "bad priority body: {body}");
    }
    // Health surface: liveness is unconditional, readiness reflects the
    // engine's drain/overload/watchdog state (all healthy here).
    {
        let resp = http_get("/healthz")?;
        anyhow::ensure!(resp.starts_with("HTTP/1.1 200"), "healthz: {resp}");
        anyhow::ensure!(resp.contains(r#""status":"ok""#), "healthz body: {resp}");
        let resp = http_get("/readyz")?;
        anyhow::ensure!(resp.starts_with("HTTP/1.1 200"), "readyz: {resp}");
        anyhow::ensure!(resp.contains(r#""ready":true"#), "readyz body: {resp}");
    }

    // Keep-alive: non-stream completion, then a second request on the
    // SAME socket; then the stream/non-stream equality check.
    let prompt = "the stream carries old light towards dawn. quiet hills ";
    let req_body = Json::obj()
        .with("prompt", prompt)
        .with("max_tokens", 12usize)
        .with("seed", 7usize)
        .to_string();
    let non_stream_text;
    {
        let stream = TcpStream::connect(ADDR)?;
        let mut writer = stream.try_clone()?;
        let mut reader = BufReader::new(stream);
        post_completions(&mut writer, &req_body)?;
        let (status, body) = read_response(&mut reader)?;
        anyhow::ensure!(status == 200, "completion: {status} {body}");
        let j = Json::parse(&body)?;
        non_stream_text = j.get("choices").unwrap().as_arr().unwrap()[0]
            .get("text")
            .and_then(Json::as_str)
            .unwrap_or("")
            .to_string();
        anyhow::ensure!(
            j.path("usage.completion_tokens").and_then(Json::as_usize) == Some(12),
            "usage: {body}"
        );
        // Socket reuse (HTTP/1.1 keep-alive).
        post_completions(&mut writer, &req_body)?;
        let (status2, body2) = read_response(&mut reader)?;
        anyhow::ensure!(status2 == 200, "keep-alive reuse: {status2} {body2}");
    }
    // Streaming: concatenated SSE chunks == the non-streaming text.
    {
        let mut s = TcpStream::connect(ADDR)?;
        let stream_body = Json::obj()
            .with("prompt", prompt)
            .with("max_tokens", 12usize)
            .with("seed", 7usize)
            .with("stream", true)
            .to_string();
        post_completions(&mut s, &stream_body)?;
        let mut raw = String::new();
        s.read_to_string(&mut raw)?; // SSE is close-delimited
        anyhow::ensure!(raw.starts_with("HTTP/1.1 200"), "stream: {raw}");
        anyhow::ensure!(raw.contains("text/event-stream"), "stream headers: {raw}");
        anyhow::ensure!(raw.trim_end().ends_with("data: [DONE]"), "stream end: {raw}");
        let (text, finish) = sse_text(&raw);
        anyhow::ensure!(
            text == non_stream_text,
            "stream text {text:?} != non-stream {non_stream_text:?}"
        );
        anyhow::ensure!(finish.as_deref() == Some("length"), "finish: {finish:?}");
    }

    // Mid-stream disconnect: start a long stream, read one chunk, drop
    // the socket. The engine must observe the cancel and free the
    // sequence's blocks (kv_blocks_used gauge returns to 0, cancelled
    // counter increments).
    {
        let mut s = TcpStream::connect(ADDR)?;
        let body = Json::obj()
            .with("prompt", prompt)
            .with("max_tokens", 512usize)
            .with("stream", true)
            .to_string();
        post_completions(&mut s, &body)?;
        let mut first = [0u8; 1];
        s.read_exact(&mut first)?; // at least the headers started
        drop(s); // client goes away mid-stream
    }
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
    loop {
        let m = http_get("/metrics")?;
        let cancelled = m
            .lines()
            .any(|l| l.starts_with("counter requests_cancelled") && !l.ends_with(" 0"));
        let blocks_free = m.lines().any(|l| l.trim() == "gauge kv_blocks_used 0");
        if cancelled && blocks_free {
            anyhow::ensure!(
                m.contains("latency_us ttft"),
                "ttft histogram missing: {m}"
            );
            anyhow::ensure!(
                m.contains("latency_us inter_token"),
                "inter_token histogram missing: {m}"
            );
            break;
        }
        anyhow::ensure!(
            std::time::Instant::now() < deadline,
            "disconnect not observed; metrics:\n{m}"
        );
        std::thread::sleep(std::time::Duration::from_millis(200));
    }

    // Graceful drain (last: the serve loop exits once idle). The drain
    // acknowledgement must arrive before shutdown; a follow-up readyz
    // sees 503 or a closed socket depending on how fast the loop exits.
    {
        let mut s = TcpStream::connect(ADDR)?;
        write!(
            s,
            "POST /admin/drain HTTP/1.1\r\nHost: x\r\nContent-Length: 0\r\nConnection: close\r\n\r\n"
        )?;
        let mut out = String::new();
        s.read_to_string(&mut out)?;
        anyhow::ensure!(out.starts_with("HTTP/1.1 200"), "drain: {out}");
        anyhow::ensure!(out.contains(r#""draining":true"#), "drain body: {out}");
        if let Ok(resp) = http_get("/readyz") {
            anyhow::ensure!(
                resp.is_empty() || resp.starts_with("HTTP/1.1 503"),
                "readyz after drain: {resp}"
            );
        }
    }
    Ok(())
}

#[test]
fn v1_http_surface_end_to_end() {
    let Some(rt) = runtime() else { return };
    let e = engine(rt);
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let client = std::thread::spawn(move || {
        let res = std::panic::catch_unwind(driver);
        stop2.store(true, Ordering::Relaxed); // always release the server
        match res {
            Ok(r) => r,
            Err(_) => Err(anyhow::anyhow!("driver panicked")),
        }
    });
    radar_serve::server::serve(e, ADDR, stop).unwrap();
    client.join().unwrap().unwrap();
}
