//! Engine integration: every policy serves a request end-to-end, the
//! vanilla policy equals the model oracle (teacher-forced PPL finite,
//! monotone context growth), batching equals sequential execution, and
//! caches are reclaimed.

use radar_serve::config::{ArtifactPaths, PolicyKind, ServingConfig};
use radar_serve::engine::{Engine, GenRequest};
use radar_serve::model::tokenizer;
use radar_serve::runtime::Runtime;
use std::sync::Arc;

fn runtime() -> Option<Arc<Runtime>> {
    let paths = ArtifactPaths::new("artifacts", "sm");
    if !paths.manifest().exists() {
        eprintln!("skipping engine tests: run `make artifacts` first");
        return None;
    }
    Some(Arc::new(Runtime::load(paths).unwrap()))
}

fn engine(rt: Arc<Runtime>, policy: PolicyKind) -> Engine {
    let mut cfg = ServingConfig::default();
    cfg.policy = policy;
    cfg.window = 32;
    cfg.budget = 64;
    Engine::new(rt, cfg).unwrap()
}

const PROMPT: &str = "the stream carries old light towards dawn. quiet hills answer slowly ";

#[test]
fn every_policy_generates() {
    let Some(rt) = runtime() else { return };
    for &p in PolicyKind::all() {
        let mut e = engine(rt.clone(), p);
        let id = e.add(GenRequest::new(tokenizer::encode(PROMPT), 8)).unwrap();
        let results = e.run_to_completion().unwrap();
        let r = results.iter().find(|r| r.id == id).unwrap();
        assert_eq!(r.logprobs.len(), 8, "{p:?}");
        assert!(r.logprobs.iter().all(|lp| lp.is_finite()), "{p:?}");
        assert!(r.ppl().is_finite() && r.ppl() > 0.0, "{p:?}");
    }
}

#[test]
fn teacher_forcing_records_logprobs() {
    let Some(rt) = runtime() else { return };
    // In-distribution text: the actual evaluation corpus.
    let corpus = std::fs::read("artifacts/corpus/book_eval.bin").unwrap();
    let mut e = engine(rt, PolicyKind::Vanilla);
    let toks = tokenizer::encode_bytes(&corpus[..160]);
    let (prompt, teacher) = toks.split_at(64);
    let id = e
        .add(GenRequest::teacher_forced(prompt.to_vec(), teacher.to_vec()))
        .unwrap();
    let results = e.run_to_completion().unwrap();
    let r = results.iter().find(|r| r.id == id).unwrap();
    assert_eq!(r.logprobs.len(), teacher.len());
    // Trained model must beat uniform (PPL 256) on in-distribution text.
    assert!(r.ppl() < 100.0, "ppl {}", r.ppl());
}

#[test]
fn radar_matches_vanilla_at_short_context() {
    // With t < budget every policy sees the whole cache, so greedy
    // generations must agree token-for-token.
    let Some(rt) = runtime() else { return };
    let gen = |p: PolicyKind| {
        let mut e = engine(rt.clone(), p);
        let id = e.add(GenRequest::new(tokenizer::encode("quiet hills "), 12)).unwrap();
        let results = e.run_to_completion().unwrap();
        results.into_iter().find(|r| r.id == id).unwrap().tokens
    };
    let v = gen(PolicyKind::Vanilla);
    let r = gen(PolicyKind::Radar);
    assert_eq!(v, r, "greedy tokens must agree at short context");
}

#[test]
fn batched_equals_sequential() {
    let Some(rt) = runtime() else { return };
    let prompts = ["the stream carries ", "old light towards ", "quiet hills answer "];
    // Sequential.
    let mut seq_out = Vec::new();
    for p in prompts {
        let mut e = engine(rt.clone(), PolicyKind::Streaming);
        let id = e.add(GenRequest::new(tokenizer::encode(p), 6)).unwrap();
        let results = e.run_to_completion().unwrap();
        seq_out.push(results.into_iter().find(|r| r.id == id).unwrap().tokens);
    }
    // Batched in one engine.
    let mut e = engine(rt, PolicyKind::Streaming);
    let ids: Vec<_> = prompts
        .iter()
        .map(|p| e.add(GenRequest::new(tokenizer::encode(p), 6)).unwrap())
        .collect();
    let results = e.run_to_completion().unwrap();
    for (i, id) in ids.iter().enumerate() {
        let r = results.iter().find(|r| r.id == *id).unwrap();
        assert_eq!(r.tokens, seq_out[i], "batched row {i} differs from sequential");
    }
}

#[test]
fn cache_blocks_reclaimed_after_removal() {
    let Some(rt) = runtime() else { return };
    let mut e = engine(rt, PolicyKind::Vanilla);
    let used0 = e.pool.used_blocks();
    let mut after = Vec::new();
    for _ in 0..3 {
        let id = e.add(GenRequest::new(tokenizer::encode(PROMPT), 4)).unwrap();
        e.run_to_completion().unwrap();
        // run_to_completion removes finished sequences.
        let _ = id;
        after.push(e.pool.used_blocks());
    }
    // Per-sequence blocks are all reclaimed; only the prefix cache's
    // intentional retention remains, and repeating the same prompt
    // must not grow it.
    assert_eq!(
        e.pool.used_blocks(),
        used0 + e.prefix.cached_blocks(),
        "blocks leak across requests"
    );
    assert_eq!(after[0], after[2], "prefix cache grows on identical prompts");
}

#[test]
fn stop_token_halts_generation() {
    let Some(rt) = runtime() else { return };
    let mut e = engine(rt, PolicyKind::Vanilla);
    let mut req = GenRequest::new(tokenizer::encode("the stream "), 64);
    req.stop_token = Some(b' ' as i32);
    let id = e.add(req).unwrap();
    let results = e.run_to_completion().unwrap();
    let r = results.iter().find(|r| r.id == id).unwrap();
    assert!(r.logprobs.len() <= 64);
    if r.logprobs.len() < 64 {
        assert_eq!(*r.tokens.last().unwrap(), b' ' as i32);
    }
}

#[test]
fn long_context_crosses_restructure_boundaries() {
    // Radar across several perfect squares (restructures at 169, 196, ...).
    let Some(rt) = runtime() else { return };
    let mut e = engine(rt, PolicyKind::Radar);
    let long_prompt: String = PROMPT.repeat(4); // ~280 bytes
    let id = e.add(GenRequest::new(tokenizer::encode(&long_prompt), 40)).unwrap();
    let results = e.run_to_completion().unwrap();
    let r = results.iter().find(|r| r.id == id).unwrap();
    assert_eq!(r.logprobs.len(), 40);
    assert!(r.logprobs.iter().all(|lp| lp.is_finite()));
}
