//! Byte-identity guarantees for incremental K/V staging.
//!
//! Every scenario runs two arenas in lockstep over the same cache and
//! selection schedule — one delta-staged, one forced to full restage —
//! and asserts the staged K/V/mask buffers are byte-identical at every
//! step: across restructure boundaries, preemption + warm re-admission,
//! prefix-seeded starts, and fused-batch S-bucket changes.

use radar_serve::config::ModelConfig;
use radar_serve::engine::staging::{
    stage_planes_serial, stage_planes_sharded, StageStats, StagedPlanes,
};
use radar_serve::kvcache::{BlockPool, SeqCache, BLOCK_TOKENS};
use radar_serve::util::prng::SplitMix64;
use radar_serve::util::threadpool::ThreadPool;

const NEG: f32 = -1e30;
const DH: usize = 8;
const NF: usize = 4;

fn cfg(layers: usize, heads: usize) -> ModelConfig {
    ModelConfig {
        name: "staging-test".into(),
        d_model: heads * DH,
        n_layers: layers,
        n_heads: heads,
        d_head: DH,
        d_ffn: 4 * heads * DH,
        n_feat: NF,
        max_train_len: 4096,
        vocab: 64,
    }
}

/// Token t's K row for plane p starts at value t*1000 + p*10; V = K + 0.5.
fn token_kv(lh: usize, t: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let k: Vec<f32> = (0..lh * DH)
        .map(|i| (t * 1000 + (i / DH) * 10) as f32 + (i % DH) as f32 * 0.01)
        .collect();
    let v: Vec<f32> = k.iter().map(|x| x + 0.5).collect();
    (k, v, vec![0.0; lh * NF])
}

fn grow(pool: &mut BlockPool, cache: &mut SeqCache, lh: usize, upto: usize) {
    while cache.len() < upto {
        let t = cache.len();
        let (k, v, f) = token_kv(lh, t);
        cache.append(pool, &k, &v, &f).unwrap();
    }
}

/// Sinks + segment picks + sliding window, sorted + deduped.
fn selection(sinks: usize, segs: &[usize], seg_len: usize, window: usize, t: usize) -> Vec<u32> {
    let mut sel: Vec<u32> = (0..sinks.min(t)).map(|x| x as u32).collect();
    for &s in segs {
        for tok in s..(s + seg_len).min(t) {
            sel.push(tok as u32);
        }
    }
    for tok in t.saturating_sub(window)..t {
        sel.push(tok as u32);
    }
    sel.sort_unstable();
    sel.dedup();
    sel
}

struct Staged {
    k: Vec<f32>,
    v: Vec<f32>,
    m: Vec<f32>,
}

#[allow(clippy::too_many_arguments)]
fn stage(
    arena: &mut StagedPlanes,
    cache: &SeqCache,
    pool: &BlockPool,
    heads: usize,
    per_plane: &[Vec<u32>],
    s: usize,
    delta: bool,
    stats: &mut StageStats,
) -> Staged {
    let lh = arena.planes.len();
    let mut out = Staged {
        k: vec![f32::NAN; lh * s * DH],
        v: vec![f32::NAN; lh * s * DH],
        m: vec![f32::NAN; lh * s],
    };
    let st = stage_planes_serial(
        &mut arena.planes, 0, heads, cache, pool, per_plane, s, &mut out.k, &mut out.v,
        &mut out.m, delta, NEG,
    );
    stats.merge(&st);
    out
}

/// Only rows [0, sel.len()) are defined output; compare those (plus the
/// full mask, which is always written).
fn assert_identical(a: &Staged, b: &Staged, per_plane: &[Vec<u32>], s: usize, what: &str) {
    assert_eq!(a.m, b.m, "{what}: mask diverged");
    for (p, sel) in per_plane.iter().enumerate() {
        let n = sel.len() * DH;
        let (ka, kb) = (&a.k[p * s * DH..p * s * DH + n], &b.k[p * s * DH..p * s * DH + n]);
        let (va, vb) = (&a.v[p * s * DH..p * s * DH + n], &b.v[p * s * DH..p * s * DH + n]);
        assert_eq!(ka, kb, "{what}: K diverged on plane {p}");
        assert_eq!(va, vb, "{what}: V diverged on plane {p}");
        assert!(ka.iter().all(|x| x.is_finite()), "{what}: K rows unwritten on plane {p}");
    }
}

#[test]
fn restructure_boundaries_stay_byte_identical() {
    let (layers, heads) = (2, 2);
    let lh = layers * heads;
    let c = cfg(layers, heads);
    let mut pool = BlockPool::new(&c, NF, 256);
    let mut cache = SeqCache::new(NF);
    grow(&mut pool, &mut cache, lh, 200);
    let mut delta_arena = StagedPlanes::new(lh);
    let mut full_arena = StagedPlanes::new(lh);
    let (mut dstats, mut fstats) = (StageStats::default(), StageStats::default());
    let mut rng = SplitMix64::new(7);
    let mut segs: Vec<Vec<usize>> = (0..lh).map(|_| vec![32, 64, 96]).collect();
    let s = 96;
    for step in 0..48 {
        let t = cache.len();
        if step % 12 == 0 && step > 0 {
            // Restructure: every plane's top-k segment set is resampled.
            for sg in &mut segs {
                *sg = (0..3).map(|_| 16 + (rng.below(9) as usize) * 16).collect();
                sg.sort_unstable();
            }
        }
        let per_plane: Vec<Vec<u32>> =
            segs.iter().map(|sg| selection(4, sg, 8, 32, t)).collect();
        let a = stage(&mut delta_arena, &cache, &pool, heads, &per_plane, s, true, &mut dstats);
        let b = stage(&mut full_arena, &cache, &pool, heads, &per_plane, s, false, &mut fstats);
        assert_identical(&a, &b, &per_plane, s, &format!("step {step}"));
        let (k, v, f) = token_kv(lh, t);
        cache.append(&mut pool, &k, &v, &f).unwrap();
    }
    assert!(dstats.delta_hits > 0, "steady steps must hit the delta path");
    assert!(
        dstats.bytes_delta < dstats.bytes_full / 2,
        "delta staging should copy far less than full re-gather \
         ({} vs {})",
        dstats.bytes_delta,
        dstats.bytes_full
    );
    assert_eq!(fstats.delta_hits, 0, "force-full must never count delta hits");
}

#[test]
fn preemption_invalidate_then_warm_readmission() {
    let (layers, heads) = (2, 2);
    let lh = layers * heads;
    let c = cfg(layers, heads);
    let mut pool = BlockPool::new(&c, NF, 256);
    let mut cache = SeqCache::new(NF);
    grow(&mut pool, &mut cache, lh, 80);
    let mut arena = StagedPlanes::new(lh);
    let mut full_arena = StagedPlanes::new(lh);
    let segs: Vec<usize> = vec![16, 48];
    let s = 64;
    let mut st = StageStats::default();
    for _ in 0..4 {
        let t = cache.len();
        let per_plane: Vec<Vec<u32>> = (0..lh).map(|_| selection(4, &segs, 8, 16, t)).collect();
        stage(&mut arena, &cache, &pool, heads, &per_plane, s, true, &mut st);
        let (k, v, f) = token_kv(lh, t);
        cache.append(&mut pool, &k, &v, &f).unwrap();
    }
    // Preemption: blocks are freed and the arena must be invalidated;
    // warm re-admission rebuilds the same logical tokens in (possibly
    // different) blocks.
    let warm_len = cache.len();
    cache.free(&mut pool).unwrap();
    arena.invalidate();
    let mut cache = SeqCache::new(NF);
    grow(&mut pool, &mut cache, lh, warm_len);
    let mut st = StageStats::default();
    let mut fstats = StageStats::default();
    for step in 0..6 {
        let t = cache.len();
        let per_plane: Vec<Vec<u32>> = (0..lh).map(|_| selection(4, &segs, 8, 16, t)).collect();
        let a = stage(&mut arena, &cache, &pool, heads, &per_plane, s, true, &mut st);
        let b = stage(&mut full_arena, &cache, &pool, heads, &per_plane, s, false, &mut fstats);
        assert_identical(&a, &b, &per_plane, s, &format!("post-preempt step {step}"));
        if step == 0 {
            assert_eq!(
                st.full_restages, lh as u64,
                "first step after invalidate must restage every plane"
            );
        }
        let (k, v, f) = token_kv(lh, t);
        cache.append(&mut pool, &k, &v, &f).unwrap();
    }
    assert!(st.delta_hits > 0, "steady decode after re-admission must delta-hit again");
}

#[test]
fn prefix_seeded_start_stages_correctly() {
    let (layers, heads) = (2, 2);
    let lh = layers * heads;
    let c = cfg(layers, heads);
    let mut pool = BlockPool::new(&c, NF, 256);
    // Donor holds the shared prompt prefix (3 full blocks).
    let mut donor = SeqCache::new(NF);
    grow(&mut pool, &mut donor, lh, 3 * BLOCK_TOKENS);
    let mut cache = SeqCache::seed_from_blocks(&mut pool, NF, &donor.blocks);
    assert_eq!(cache.len(), 3 * BLOCK_TOKENS);
    // The seeded sequence decodes its own distinct continuation.
    let cont_base = 1000;
    for i in 0..10 {
        let (k, v, f) = token_kv(lh, cont_base + i);
        cache.append(&mut pool, &k, &v, &f).unwrap();
    }
    let mut arena = StagedPlanes::new(lh);
    let mut full_arena = StagedPlanes::new(lh);
    let (mut st, mut fstats) = (StageStats::default(), StageStats::default());
    let segs: Vec<usize> = vec![8, 24];
    let s = 64;
    for step in 0..8 {
        let t = cache.len();
        // Window spans the seeded-prefix / continuation boundary.
        let per_plane: Vec<Vec<u32>> = (0..lh).map(|_| selection(2, &segs, 8, 24, t)).collect();
        let a = stage(&mut arena, &cache, &pool, heads, &per_plane, s, true, &mut st);
        let b = stage(&mut full_arena, &cache, &pool, heads, &per_plane, s, false, &mut fstats);
        assert_identical(&a, &b, &per_plane, s, &format!("seeded step {step}"));
        let (k, v, f) = token_kv(lh, cont_base + 100 + step);
        cache.append(&mut pool, &k, &v, &f).unwrap();
    }
    assert!(st.delta_hits > 0);
    donor.free(&mut pool).unwrap();
    cache.free(&mut pool).unwrap();
}

#[test]
fn bucket_changes_do_not_force_restage() {
    let (layers, heads) = (1, 2);
    let lh = layers * heads;
    let c = cfg(layers, heads);
    let mut pool = BlockPool::new(&c, NF, 256);
    let mut cache = SeqCache::new(NF);
    grow(&mut pool, &mut cache, lh, 96);
    let mut arena = StagedPlanes::new(lh);
    let mut full_arena = StagedPlanes::new(lh);
    let segs: Vec<usize> = vec![16, 40];
    // Fused batching re-buckets S every step; the tightly packed arena
    // must keep delta-hitting regardless.
    let buckets = [48usize, 64, 96, 56, 64];
    let mut st = StageStats::default();
    let mut fstats = StageStats::default();
    for (step, &s) in buckets.iter().enumerate() {
        let t = cache.len();
        let per_plane: Vec<Vec<u32>> = (0..lh).map(|_| selection(4, &segs, 8, 12, t)).collect();
        assert!(per_plane.iter().all(|p| p.len() <= s));
        let a = stage(&mut arena, &cache, &pool, heads, &per_plane, s, true, &mut st);
        let b = stage(&mut full_arena, &cache, &pool, heads, &per_plane, s, false, &mut fstats);
        assert_identical(&a, &b, &per_plane, s, &format!("bucket {s} (step {step})"));
        let (k, v, f) = token_kv(lh, t);
        cache.append(&mut pool, &k, &v, &f).unwrap();
    }
    // Steps after the first are all delta hits despite bucket churn.
    assert_eq!(st.full_restages, lh as u64, "only the cold start restages");
    assert_eq!(st.delta_hits, (buckets.len() as u64 - 1) * lh as u64);
}

#[test]
fn sharded_staging_matches_serial_over_random_walk() {
    let (layers, heads) = (4, 4);
    let lh = layers * heads;
    let c = cfg(layers, heads);
    let mut pool = BlockPool::new(&c, NF, 512);
    let mut cache = SeqCache::new(NF);
    grow(&mut pool, &mut cache, lh, 160);
    let tp = ThreadPool::new(4, "staging-test");
    let mut serial_arena = StagedPlanes::new(lh);
    let mut sharded_arena = StagedPlanes::new(lh);
    let mut rng = SplitMix64::new(0xBEEF);
    let s = 96;
    for step in 0..24 {
        let t = cache.len() as u64;
        let per_plane: Vec<Vec<u32>> = (0..lh)
            .map(|p| {
                if (step + p) % 7 == 0 {
                    return Vec::new(); // empty-selection plane
                }
                let n = 1 + rng.below(64) as usize;
                let mut sel: Vec<u32> = (0..n).map(|_| rng.below(t) as u32).collect();
                sel.sort_unstable();
                sel.dedup();
                sel
            })
            .collect();
        let mut a = Staged {
            k: vec![0.0; lh * s * DH],
            v: vec![0.0; lh * s * DH],
            m: vec![0.0; lh * s],
        };
        let mut b = Staged {
            k: vec![0.0; lh * s * DH],
            v: vec![0.0; lh * s * DH],
            m: vec![0.0; lh * s],
        };
        let st_a = stage_planes_serial(
            &mut serial_arena.planes, 0, heads, &cache, &pool, &per_plane, s, &mut a.k,
            &mut a.v, &mut a.m, true, NEG,
        );
        let st_b = stage_planes_sharded(
            &tp, 4, &mut sharded_arena.planes, 0, heads, &cache, &pool, &per_plane, s,
            &mut b.k, &mut b.v, &mut b.m, true, NEG,
        );
        assert_eq!(a.k, b.k, "step {step}: sharded K diverged");
        assert_eq!(a.v, b.v, "step {step}: sharded V diverged");
        assert_eq!(a.m, b.m, "step {step}: sharded mask diverged");
        assert_eq!(st_a, st_b, "step {step}: stats diverged");
        let (k, v, f) = token_kv(lh, cache.len());
        cache.append(&mut pool, &k, &v, &f).unwrap();
    }
}
