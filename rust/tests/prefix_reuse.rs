//! Shared-prefix KV reuse integration: the pool/tree lifecycle without
//! artifacts, plus (artifact-gated) end-to-end warm starts — a second
//! session sharing a long prompt prefix prefills only its suffix and
//! still generates byte-identical tokens, and the eviction budget never
//! frees blocks a live sequence reads.

use radar_serve::config::{ArtifactPaths, ModelConfig, PolicyKind, ServingConfig};
use radar_serve::engine::{Engine, GenRequest};
use radar_serve::kvcache::{BlockPool, SeqCache};
use radar_serve::model::tokenizer;
use radar_serve::prefix::PrefixIndex;
use radar_serve::runtime::Runtime;
use std::sync::Arc;

// -----------------------------------------------------------------
// Pool + tree lifecycle (no artifacts needed)
// -----------------------------------------------------------------

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        name: "t".into(),
        d_model: 8,
        n_layers: 2,
        n_heads: 2,
        d_head: 4,
        d_ffn: 16,
        n_feat: 8,
        max_train_len: 64,
        vocab: 256,
    }
}

/// Deterministic per-token K/V/feature rows in the [L*H, d] source
/// layout `SeqCache::append` takes.
fn tok_kvf(c: &ModelConfig, i: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let lh = c.n_layers * c.n_heads;
    let k: Vec<f32> = (0..lh * c.d_head).map(|j| (i * 100 + j) as f32).collect();
    let v: Vec<f32> = k.iter().map(|x| x + 0.5).collect();
    let f: Vec<f32> = (0..lh * c.n_feat).map(|j| (i * 7 + j) as f32).collect();
    (k, v, f)
}

#[test]
fn tree_keeps_donor_blocks_alive_and_seeds_identical_reads() {
    let c = tiny_cfg();
    let mut pool = BlockPool::new(&c, c.n_feat, 64);
    let mut tree = PrefixIndex::new(1 << 20, pool.block_bytes());

    // Donor session prefills 32 tokens (2 full blocks) and registers
    // them, then exits.
    let prompt: Vec<i32> = (0..40).map(|t| (t % 7) as i32).collect();
    let mut donor = SeqCache::new(c.n_feat);
    for i in 0..32 {
        let (k, v, f) = tok_kvf(&c, i);
        donor.append(&mut pool, &k, &v, &f).unwrap();
    }
    tree.insert(&mut pool, &prompt[..32], &donor.blocks[..2], None);
    assert_eq!(tree.cached_blocks(), 2);
    donor.free(&mut pool).unwrap();
    assert_eq!(pool.used_blocks(), 2, "tree must keep the blocks alive");

    // A warm session matching the prefix seeds from the tree and reads
    // exactly what the donor wrote.
    let m = tree.probe(&prompt, prompt.len() - 1);
    assert_eq!(m.tokens, 32);
    let mut warm = SeqCache::seed_from_blocks(&mut pool, c.n_feat, &m.blocks);
    assert_eq!(warm.len(), 32);
    assert_eq!(warm.shared_blocks(&pool), 2);
    let (k5, _, _) = tok_kvf(&c, 5);
    let p = c.n_heads + 1; // plane (l=1, h=1)
    assert_eq!(warm.key(&pool, 1, 1, 5), &k5[p * c.d_head..(p + 1) * c.d_head]);

    // Decoding past the shared prefix allocates fresh blocks; the
    // shared ones stay shared.
    for i in 32..40 {
        let (k, v, f) = tok_kvf(&c, i);
        warm.append(&mut pool, &k, &v, &f).unwrap();
    }
    assert_eq!(warm.len(), 40);
    assert_eq!(warm.shared_blocks(&pool), 2);

    // Dropping the whole tree while the warm session is live only
    // drops the tree's references — the reader's view is intact.
    tree.clear(&mut pool).unwrap();
    assert_eq!(tree.cached_blocks(), 0);
    let (k9, _, _) = tok_kvf(&c, 9);
    assert_eq!(warm.key(&pool, 0, 1, 9), &k9[c.d_head..2 * c.d_head]);
    warm.free(&mut pool).unwrap();
    assert_eq!(pool.used_blocks(), 0, "all blocks reclaimed at the end");
}

// -----------------------------------------------------------------
// End-to-end (artifact-gated, same pattern as engine_e2e.rs)
// -----------------------------------------------------------------

fn runtime() -> Option<Arc<Runtime>> {
    let paths = ArtifactPaths::new("artifacts", "sm");
    if !paths.manifest().exists() {
        eprintln!("skipping prefix-reuse e2e tests: run `make artifacts` first");
        return None;
    }
    Some(Arc::new(Runtime::load(paths).unwrap()))
}

#[test]
fn warm_second_session_prefills_only_its_suffix() {
    let Some(rt) = runtime() else { return };
    // 86 shared prompt tokens (byte tokenizer) = 5 full shared blocks.
    let shared = "the stream carries old light towards dawn. ".repeat(2);
    let p1 = format!("{shared}red fox jumps");
    let p2 = format!("{shared}blue owls wait");
    let mk = |cache_on: bool| {
        let mut cfg = ServingConfig::default();
        cfg.policy = PolicyKind::Radar;
        cfg.prefix_cache = cache_on;
        Engine::new(rt.clone(), cfg).unwrap()
    };

    let mut e = mk(true);
    let id1 = e.add(GenRequest::new(tokenizer::encode(&p1), 8)).unwrap();
    e.run_to_completion().unwrap();
    let _ = id1;
    let prefill_cold = e.metrics.counter("prefill_tokens");
    assert_eq!(e.metrics.counter("prefix_hits"), 0);
    assert_eq!(e.metrics.counter("prefix_misses"), 1);

    let t2 = tokenizer::encode(&p2);
    let total2 = t2.len() - 1; // last prompt token decodes, not prefills
    let id2 = e.add(GenRequest::new(t2.clone(), 8)).unwrap();
    // While the warm sequence lives, its seeded blocks are shared with
    // the tree.
    assert!(
        e.prefix.shared_blocks(&e.pool) >= 4,
        "expected >=4 shared blocks, saw {}",
        e.prefix.shared_blocks(&e.pool)
    );
    let results = e.run_to_completion().unwrap();
    let warm_tokens = results.iter().find(|r| r.id == id2).unwrap().tokens.clone();

    assert_eq!(e.metrics.counter("prefix_hits"), 1);
    assert_eq!(e.metrics.histogram_count("prefill_tokens_saved"), 1);
    let cached = e.metrics.histogram_mean("prefill_tokens_saved") as usize;
    assert!(cached >= 4 * 16, "expected a >=4-block prefix hit, got {cached} tokens");
    let prefill_warm = (e.metrics.counter("prefill_tokens") - prefill_cold) as usize;
    assert_eq!(prefill_warm, total2 - cached, "warm prefill must cover only the suffix");

    // Byte-identical output vs a cold engine with the cache disabled.
    let mut cold = mk(false);
    let idc = cold.add(GenRequest::new(t2, 8)).unwrap();
    let rc = cold.run_to_completion().unwrap();
    let cold_tokens = rc.iter().find(|r| r.id == idc).unwrap().tokens.clone();
    assert_eq!(warm_tokens, cold_tokens, "warm start changed sampled tokens");
    assert_eq!(
        cold.metrics.counter("prefix_hits") + cold.metrics.counter("prefix_misses"),
        0,
        "disabled cache must not probe"
    );
}

#[test]
fn per_request_opt_out_skips_the_cache() {
    let Some(rt) = runtime() else { return };
    let mut cfg = ServingConfig::default();
    cfg.policy = PolicyKind::Vanilla;
    let mut e = Engine::new(rt, cfg).unwrap();
    let prompt = tokenizer::encode(&"old light towards dawn. ".repeat(4));
    e.add(GenRequest::new(prompt.clone(), 4)).unwrap();
    e.run_to_completion().unwrap();

    let mut req = GenRequest::new(prompt, 4);
    req.prefix_cache = false; // the API's `cache: off`
    e.add(req).unwrap();
    e.run_to_completion().unwrap();
    assert_eq!(e.metrics.counter("prefix_hits"), 0, "opted-out request still probed");
}

#[test]
fn eviction_stays_under_budget_without_corrupting_live_sequences() {
    let Some(rt) = runtime() else { return };
    let mut cfg = ServingConfig::default();
    cfg.policy = PolicyKind::Vanilla;
    // 1 MiB holds only a handful of sm blocks, so disjoint prompts
    // force LRU leaf eviction on every registration.
    cfg.prefix_cache_mb = 1;
    let mut e = Engine::new(rt, cfg).unwrap();
    let budget = 1usize << 20;
    let stems = ["alpha ", "bravo ", "delta ", "omega "];
    for stem in stems {
        let prompt = tokenizer::encode(&stem.repeat(14)); // ~84 tokens, 5 blocks
        let id = e.add(GenRequest::new(prompt, 4)).unwrap();
        // Eviction runs inside registration while this sequence is
        // live; a freed live block would corrupt generation or trip
        // the pool's double-free check before these asserts.
        let results = e.run_to_completion().unwrap();
        let r = results.iter().find(|r| r.id == id).unwrap();
        assert!(r.ppl().is_finite() && r.logprobs.len() == 4);
        assert!(
            e.prefix.bytes_used() <= budget,
            "tree over budget: {} > {budget}",
            e.prefix.bytes_used()
        );
    }
    assert!(e.prefix.evictions > 0, "budget pressure never evicted");
    // Every per-sequence block was reclaimed; only the (under-budget)
    // tree retention remains.
    assert_eq!(e.pool.used_blocks(), e.prefix.cached_blocks());
}
