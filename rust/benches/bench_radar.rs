//! Microbenchmarks of the L3 hot-path pieces (criterion is unavailable
//! offline; uses the in-tree warmup+measure harness). Run via
//! `cargo bench --offline`.

use radar_serve::config::ModelConfig;
use radar_serve::kvcache::{BlockPool, SeqCache};
use radar_serve::radar::{top_k_indices, RadarIndex};
use radar_serve::util::prng::SplitMix64;
use radar_serve::util::stats::bench_loop;

fn cfg() -> ModelConfig {
    ModelConfig {
        name: "sm".into(),
        d_model: 128,
        n_layers: 4,
        n_heads: 2,
        d_head: 64,
        d_ffn: 512,
        n_feat: 128,
        max_train_len: 512,
        vocab: 256,
    }
}

fn build_cache(t: usize, c: &ModelConfig) -> (BlockPool, SeqCache) {
    let mut pool = BlockPool::new(c, c.n_feat, t / 16 + 2);
    let mut seq = SeqCache::new(c.n_feat);
    let lh = c.n_lh();
    let mut rng = SplitMix64::new(1);
    let k: Vec<f32> = (0..lh * c.d_head).map(|_| rng.next_f32()).collect();
    let f: Vec<f32> = (0..lh * c.n_feat).map(|_| rng.next_f32()).collect();
    for _ in 0..t {
        seq.append(&mut pool, &k, &k.clone(), &f).unwrap();
    }
    (pool, seq)
}

fn main() {
    let c = cfg();
    let mut results = Vec::new();

    // Segment scoring (Eq. 6) at several context lengths.
    for t in [1024usize, 4096, 16384] {
        let (pool, seq) = build_cache(t, &c);
        let mut idx = RadarIndex::new(c.n_lh(), c.n_feat);
        idx.force_restructure(&seq, &pool);
        let q: Vec<f32> = (0..c.n_feat).map(|i| (i as f32 * 0.01).sin()).collect();
        let mut out = Vec::new();
        results.push(bench_loop(
            &format!("segment_scores t={t} (n_segs={})", idx.n_segs),
            10,
            2000,
            2.0,
            || {
                idx.scores(0, &q, &mut out);
                std::hint::black_box(&out);
            },
        ));
        // Top-k over those scores.
        idx.scores(0, &q, &mut out);
        results.push(bench_loop(
            &format!("top_k_indices k=8 of {}", out.len()),
            10,
            5000,
            1.0,
            || {
                std::hint::black_box(top_k_indices(&out, 8));
            },
        ));
    }

    // Restructure cost (the amortized O(t) operation).
    for t in [1024usize, 4096, 16384] {
        let (pool, seq) = build_cache(t, &c);
        let mut idx = RadarIndex::new(c.n_lh(), c.n_feat);
        results.push(bench_loop(
            &format!("restructure t={t}"),
            2,
            50,
            3.0,
            || {
                idx.force_restructure(&seq, &pool);
            },
        ));
    }

    // Gather (the per-step memcpy): radar-sized vs vanilla-sized.
    {
        let t = 4096;
        let (pool, seq) = build_cache(t, &c);
        let mut rng = SplitMix64::new(3);
        for (label, n_sel) in [("radar ~600", 600usize), ("vanilla 4096", 4096)] {
            let sel: Vec<u32> = if n_sel >= t {
                (0..t as u32).collect()
            } else {
                let mut s: Vec<u32> = rng
                    .sample_indices(t, n_sel)
                    .into_iter()
                    .map(|x| x as u32)
                    .collect();
                s.sort_unstable();
                s
            };
            let mut dk = vec![0.0f32; sel.len().next_power_of_two() * c.d_head];
            let mut dv = dk.clone();
            results.push(bench_loop(
                &format!("gather_plane {label} @t={t}"),
                5,
                2000,
                2.0,
                || {
                    seq.gather_plane(&pool, 0, 0, &sel, &mut dk, &mut dv);
                    std::hint::black_box(&dk);
                },
            ));
        }
    }

    println!("\n== bench_radar (hot-path micro) ==");
    for r in &results {
        println!("{}", r.report_line());
    }
}
