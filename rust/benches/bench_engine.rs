//! End-to-end decode benchmarks (Alg. 1's O(sqrt t)/step claim and the
//! Fig. 2 second-row timing curves): per-token decode latency vs
//! context length for vanilla vs radar vs streaming, plus batched
//! throughput. Requires `make artifacts`.

use radar_serve::config::{ArtifactPaths, PolicyKind, ServingConfig};
use radar_serve::engine::{Engine, GenRequest};
use radar_serve::model::tokenizer;
use radar_serve::runtime::Runtime;
use radar_serve::workload::load_corpus;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let paths = ArtifactPaths::new("artifacts", "sm");
    if !paths.manifest().exists() {
        eprintln!("skipping bench_engine: run `make artifacts` first");
        return Ok(());
    }
    let rt = Arc::new(Runtime::load(paths.clone())?);
    let corpus = load_corpus(&paths, "book_eval.bin")?;

    println!("\n== bench_engine: per-token decode latency vs context length ==");
    println!(
        "{:<12} {:>8} {:>14} {:>12}",
        "policy", "t", "ms/token", "tok/s"
    );
    let lens = [512usize, 1024, 2048, 3072];
    for policy in [PolicyKind::Vanilla, PolicyKind::Streaming, PolicyKind::Radar] {
        for &t in &lens {
            let mut cfg = ServingConfig::default();
            cfg.policy = policy;
            cfg.window = 64;
            cfg.budget = 192;
            let mut engine = Engine::new(rt.clone(), cfg)?;
            // Prefill to t, then decode: 8 warmup steps (amortize
            // lazy artifact compilation) + a measured window of 64.
            let toks = tokenizer::encode_bytes(&corpus[..t + 73]);
            let req = GenRequest::teacher_forced(toks[..t].to_vec(), toks[t..].to_vec());
            let id = engine.add(req)?;
            for _ in 0..8 {
                engine.step()?;
            }
            let warm = engine.seq(id).unwrap().logprobs.len();
            let t0 = std::time::Instant::now();
            while !engine.active_ids().is_empty() {
                engine.step()?;
            }
            let el = t0.elapsed().as_secs_f64();
            let res = engine.remove(id).unwrap();
            let n = (res.logprobs.len() - warm) as f64;
            println!(
                "{:<12} {:>8} {:>14.2} {:>12.1}",
                policy.name(),
                t,
                el * 1e3 / n,
                n / el
            );
        }
    }

    println!("\n== bench_engine: batched decode throughput (radar) ==");
    println!("{:<8} {:>14} {:>12}", "batch", "ms/token/seq", "agg tok/s");
    for b in [1usize, 2, 4] {
        let mut cfg = ServingConfig::default();
        cfg.policy = PolicyKind::Streaming; // fused path batches
        cfg.max_batch = b;
        let mut engine = Engine::new(rt.clone(), cfg)?;
        let mut ids = Vec::new();
        for i in 0..b {
            let off = i * 700;
            let toks = tokenizer::encode_bytes(&corpus[off..off + 577]);
            ids.push(engine.add(GenRequest::teacher_forced(
                toks[..512].to_vec(),
                toks[512..].to_vec(),
            ))?);
        }
        for _ in 0..4 {
            engine.step()?; // warmup: compile the (B, S) bucket
        }
        let t0 = std::time::Instant::now();
        while !engine.active_ids().is_empty() {
            engine.step()?;
        }
        let el = t0.elapsed().as_secs_f64();
        let total: usize = ids
            .iter()
            .map(|&id| engine.remove(id).unwrap().logprobs.len().saturating_sub(4))
            .sum();
        println!(
            "{:<8} {:>14.2} {:>12.1}",
            b,
            el * 1e3 / (total as f64 / b as f64),
            total as f64 / el
        );
    }
    Ok(())
}
