//! End-to-end serving driver (the DESIGN.md §6 "e2e validation" run):
//! starts the HTTP server with the Radar policy, fires a batch of
//! concurrent long-context requests at it over real sockets, and
//! reports latency percentiles + throughput.
//!
//!   cargo run --release --offline --example serve_longcontext

use radar_serve::config::{ArtifactPaths, PolicyKind, ServingConfig};
use radar_serve::engine::Engine;
use radar_serve::runtime::Runtime;
use radar_serve::util::json::Json;
use radar_serve::util::stats::Series;
use radar_serve::workload::load_corpus;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

const ADDR: &str = "127.0.0.1:18477";

fn post_generate(prompt: &str, max_new: usize) -> anyhow::Result<Json> {
    let body = Json::obj()
        .with("prompt", prompt)
        .with("max_new_tokens", max_new)
        .to_string();
    let mut stream = TcpStream::connect(ADDR)?;
    write!(
        stream,
        "POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        body
    )?;
    let mut resp = String::new();
    stream.read_to_string(&mut resp)?;
    let json_start = resp.find("\r\n\r\n").map(|i| i + 4).unwrap_or(0);
    Ok(Json::parse(&resp[json_start..])?)
}

fn main() -> anyhow::Result<()> {
    // PJRT handles are !Send, so the engine + server loop stay on the
    // MAIN thread; the client load generator runs on spawned threads
    // and flips `stop` when done (the standard leader/worker shape).
    let rt = Arc::new(Runtime::load(ArtifactPaths::new("artifacts", "sm"))?);
    let corpus = load_corpus(&ArtifactPaths::new("artifacts", "sm"), "book_eval.bin")?;
    let mut cfg = ServingConfig::default();
    cfg.policy = PolicyKind::Radar;
    let engine = Engine::new(rt, cfg)?;
    let stop = Arc::new(AtomicBool::new(false));

    let stop_driver = stop.clone();
    let driver = std::thread::spawn(move || -> anyhow::Result<()> {
        // Wait for the listener.
        for _ in 0..100 {
            if TcpStream::connect(ADDR).is_ok() {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(100));
        }
        // Health check.
        let mut s = TcpStream::connect(ADDR)?;
        write!(s, "GET /health HTTP/1.1\r\n\r\n")?;
        let mut health = String::new();
        s.read_to_string(&mut health)?;
        anyhow::ensure!(health.contains("\"status\":\"ok\""), "health: {health}");
        println!("server healthy at {ADDR}");

        // Fire concurrent long-context requests from client threads.
        let n_clients = 4;
        let reqs_per_client = 3;
        let prompt_len = 640usize;
        let max_new = 32usize;
        let t0 = std::time::Instant::now();
        let handles: Vec<_> = (0..n_clients)
            .map(|c| {
                let corpus = corpus.clone();
                std::thread::spawn(move || -> anyhow::Result<Vec<f64>> {
                    let mut lat = Vec::new();
                    for r in 0..reqs_per_client {
                        let off = (c * 7919 + r * 104729) % (corpus.len() - prompt_len);
                        let prompt = String::from_utf8_lossy(&corpus[off..off + prompt_len])
                            .into_owned();
                        let t = std::time::Instant::now();
                        let resp = post_generate(&prompt, max_new)?;
                        let el = t.elapsed().as_secs_f64();
                        anyhow::ensure!(
                            resp.get("tokens").and_then(Json::as_usize) == Some(max_new),
                            "bad response: {resp}"
                        );
                        lat.push(el);
                    }
                    Ok(lat)
                })
            })
            .collect();
        let mut lat = Series::new();
        let mut n_ok = 0;
        for h in handles {
            for l in h.join().unwrap()? {
                lat.push(l * 1e3);
                n_ok += 1;
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "{n_ok} requests ({prompt_len} prompt bytes, {max_new} new tokens each) in {wall:.1}s"
        );
        println!(
            "request latency ms: mean {:.0}  p50 {:.0}  p99 {:.0}",
            lat.mean(),
            lat.p50(),
            lat.p99()
        );
        println!(
            "throughput: {:.2} req/s, {:.1} generated tok/s",
            n_ok as f64 / wall,
            (n_ok * max_new) as f64 / wall
        );

        // Metrics endpoint.
        let mut s = TcpStream::connect(ADDR)?;
        write!(s, "GET /metrics HTTP/1.1\r\n\r\n")?;
        let mut m = String::new();
        s.read_to_string(&mut m)?;
        let counters: Vec<&str> = m.lines().filter(|l| l.starts_with("counter")).collect();
        println!("server counters: {counters:?}");
        stop_driver.store(true, Ordering::Relaxed);
        Ok(())
    });

    radar_serve::server::serve(engine, ADDR, stop)?;
    driver.join().unwrap()?;
    Ok(())
}
