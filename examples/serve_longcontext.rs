//! End-to-end serving driver (the DESIGN.md §6 "e2e validation" run):
//! starts the HTTP server with the Radar policy, fires a batch of
//! concurrent long-context `/v1/completions` requests at it over real
//! sockets (keep-alive, non-stream and SSE stream), and reports latency
//! percentiles + throughput.
//!
//!   cargo run --release --offline --example serve_longcontext

use radar_serve::config::{ArtifactPaths, PolicyKind, ServingConfig};
use radar_serve::engine::Engine;
use radar_serve::runtime::Runtime;
use radar_serve::util::json::Json;
use radar_serve::util::stats::Series;
use radar_serve::workload::load_corpus;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

const ADDR: &str = "127.0.0.1:18477";

/// Read one HTTP response off a keep-alive socket: status line +
/// headers, then exactly Content-Length body bytes.
fn read_response(reader: &mut BufReader<TcpStream>) -> anyhow::Result<(u16, String)> {
    let mut status_line = String::new();
    reader.read_line(&mut status_line)?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow::anyhow!("bad status line: {status_line:?}"))?;
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        if h.trim().is_empty() {
            break;
        }
        if let Some((name, value)) = h.split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value.trim().parse().unwrap_or(0);
            }
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok((status, String::from_utf8_lossy(&body).into_owned()))
}

fn post_body(prompt: &str, max_tokens: usize, stream: bool) -> String {
    Json::obj()
        .with("prompt", prompt)
        .with("max_tokens", max_tokens)
        .with("stream", stream)
        .to_string()
}

fn write_post(stream: &mut TcpStream, body: &str) -> anyhow::Result<()> {
    write!(
        stream,
        "POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        body
    )?;
    Ok(())
}

/// One keep-alive socket, `n` sequential completions. Returns per-request
/// latencies (proving socket reuse works).
fn run_client(n: usize, client_id: usize, corpus: &[u8], prompt_len: usize, max_tokens: usize)
    -> anyhow::Result<Vec<f64>> {
    let stream = TcpStream::connect(ADDR)?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut lat = Vec::new();
    for r in 0..n {
        let off = (client_id * 7919 + r * 104729) % (corpus.len() - prompt_len);
        let prompt = String::from_utf8_lossy(&corpus[off..off + prompt_len]).into_owned();
        let t = std::time::Instant::now();
        write_post(&mut writer, &post_body(&prompt, max_tokens, false))?;
        let (status, body) = read_response(&mut reader)?;
        anyhow::ensure!(status == 200, "status {status}: {body}");
        let j = Json::parse(&body)?;
        anyhow::ensure!(
            j.path("usage.completion_tokens").and_then(Json::as_usize) == Some(max_tokens),
            "bad response: {body}"
        );
        lat.push(t.elapsed().as_secs_f64());
    }
    Ok(lat)
}

/// One SSE stream; returns the number of token chunks and the
/// concatenated text.
fn run_stream(prompt: &str, max_tokens: usize) -> anyhow::Result<(usize, String)> {
    let mut stream = TcpStream::connect(ADDR)?;
    write_post(&mut stream, &post_body(prompt, max_tokens, true))?;
    let mut raw = String::new();
    stream.read_to_string(&mut raw)?; // SSE responses are close-delimited
    let mut chunks = 0usize;
    let mut text = String::new();
    for line in raw.lines() {
        let Some(payload) = line.strip_prefix("data: ") else { continue };
        if payload == "[DONE]" {
            break;
        }
        let j = Json::parse(payload)?;
        let Some(choice) = j.get("choices").and_then(Json::as_arr).and_then(<[Json]>::first)
        else {
            continue;
        };
        text.push_str(choice.get("text").and_then(Json::as_str).unwrap_or(""));
        if choice.get("finish_reason") == Some(&Json::Null) {
            chunks += 1; // token chunk (terminal chunk carries a reason)
        }
    }
    Ok((chunks, text))
}

fn http_get(path: &str) -> anyhow::Result<String> {
    let mut s = TcpStream::connect(ADDR)?;
    write!(s, "GET {path} HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n")?;
    let mut resp = String::new();
    s.read_to_string(&mut resp)?;
    Ok(resp)
}

fn main() -> anyhow::Result<()> {
    // PJRT handles are !Send, so the engine + server loop stay on the
    // MAIN thread; the client load generator runs on spawned threads
    // and flips `stop` when done (the standard leader/worker shape).
    let rt = Arc::new(Runtime::load(ArtifactPaths::new("artifacts", "sm"))?);
    let corpus = load_corpus(&ArtifactPaths::new("artifacts", "sm"), "book_eval.bin")?;
    let mut cfg = ServingConfig::default();
    cfg.policy = PolicyKind::Radar;
    let engine = Engine::new(rt, cfg)?;
    let stop = Arc::new(AtomicBool::new(false));

    let stop_driver = stop.clone();
    let driver = std::thread::spawn(move || -> anyhow::Result<()> {
        // Wait for the listener.
        for _ in 0..100 {
            if TcpStream::connect(ADDR).is_ok() {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(100));
        }
        let health = http_get("/health")?;
        anyhow::ensure!(health.contains("\"status\":\"ok\""), "health: {health}");
        println!("server healthy at {ADDR}");

        // Concurrent clients, each reusing ONE keep-alive socket.
        let n_clients = 4;
        let reqs_per_client = 3;
        let prompt_len = 640usize;
        let max_tokens = 32usize;
        let t0 = std::time::Instant::now();
        let handles: Vec<_> = (0..n_clients)
            .map(|c| {
                let corpus = corpus.clone();
                std::thread::spawn(move || {
                    run_client(reqs_per_client, c, &corpus, prompt_len, max_tokens)
                })
            })
            .collect();
        let mut lat = Series::new();
        let mut n_ok = 0;
        for h in handles {
            for l in h.join().unwrap()? {
                lat.push(l * 1e3);
                n_ok += 1;
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "{n_ok} requests ({prompt_len} prompt bytes, {max_tokens} new tokens each, keep-alive) in {wall:.1}s"
        );
        println!(
            "request latency ms: mean {:.0}  p50 {:.0}  p99 {:.0}",
            lat.mean(),
            lat.p50(),
            lat.p99()
        );
        println!(
            "throughput: {:.2} req/s, {:.1} generated tok/s",
            n_ok as f64 / wall,
            (n_ok * max_tokens) as f64 / wall
        );

        // One SSE stream: token chunks arrive incrementally.
        let off = 1234 % (corpus.len() - prompt_len);
        let prompt = String::from_utf8_lossy(&corpus[off..off + prompt_len]).into_owned();
        let (chunks, text) = run_stream(&prompt, max_tokens)?;
        anyhow::ensure!(chunks == max_tokens, "expected {max_tokens} chunks, got {chunks}");
        println!("stream: {chunks} SSE chunks, {} bytes of text", text.len());

        // Metrics endpoint: serving counters + session histograms.
        let m = http_get("/metrics")?;
        let interesting: Vec<&str> = m
            .lines()
            .filter(|l| {
                l.starts_with("counter") || l.starts_with("gauge") || l.contains("ttft")
                    || l.contains("inter_token")
            })
            .collect();
        println!("server metrics:");
        for l in interesting {
            println!("  {l}");
        }
        stop_driver.store(true, Ordering::Relaxed);
        Ok(())
    });

    radar_serve::server::serve(engine, ADDR, stop)?;
    driver.join().unwrap()?;
    Ok(())
}
