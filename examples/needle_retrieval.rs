//! Needle retrieval: the information-loss demonstration from the
//! paper's introduction. A key/value binding is planted deep in the
//! context; the probe at the end repeats the binding prefix
//! (`<<k17=`) and we measure the teacher-forced log-likelihood of the
//! correct value bytes. StreamingLLM evicts the binding once the
//! depth exceeds its window (likelihood collapses to the ~uniform
//! digit prior); Radar's segment search retrieves it.
//!
//!   cargo run --release --offline --example needle_retrieval

use radar_serve::config::{ArtifactPaths, PolicyKind, ServingConfig};
use radar_serve::engine::{Engine, GenRequest};
use radar_serve::model::tokenizer;
use radar_serve::runtime::Runtime;
use radar_serve::workload::make_needle;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let paths = ArtifactPaths::new("artifacts", "sm");
    let rt = Arc::new(Runtime::load(paths.clone())?);
    // Clean filler (drill-style words, no competing <<k=v>> bindings).
    let mut filler = Vec::new();
    let words = ["so ", "then ", "and ", "yet ", "while ", "for "];
    let mut i = 0usize;
    while filler.len() < 8192 {
        filler.extend_from_slice(words[i % words.len()].as_bytes());
        i = i.wrapping_mul(31).wrapping_add(7);
    }
    let total_len = 448usize; // inside the native context
    let depths = [64usize, 128, 192, 256, 320];
    let policies =
        [PolicyKind::Streaming, PolicyKind::H2O, PolicyKind::Radar, PolicyKind::Vanilla];
    let trials = 6;

    println!(
        "needle answer log-likelihood (nats/byte; higher = retrieved).\n\
         context {total_len} bytes, {trials} trials; uniform-digit floor ~ -5.5\n"
    );
    print!("{:<12}", "depth-back");
    for p in policies {
        print!(" {:>10}", p.name());
    }
    println!();

    for depth in depths {
        print!("{:<12}", depth);
        for policy in policies {
            let mut lp_sum = 0.0;
            let mut lp_n = 0usize;
            for trial in 0..trials {
                let needle = make_needle(&filler, total_len, depth, 100 + trial);
                let mut cfg = ServingConfig::default();
                cfg.policy = policy;
                cfg.window = 32; // small window: the needle falls outside
                cfg.budget = 32;
                let mut engine = Engine::new(rt.clone(), cfg)?;
                let prompt = tokenizer::encode_bytes(&needle.prompt);
                let answer = tokenizer::encode(&needle.answer);
                let id = engine.add(GenRequest::teacher_forced(prompt, answer))?;
                let results = engine.run_to_completion()?;
                let res = results.into_iter().find(|r| r.id == id).unwrap();
                lp_sum += res.logprobs.iter().sum::<f64>();
                lp_n += res.logprobs.len();
            }
            print!(" {:>10.2}", lp_sum / lp_n as f64);
        }
        println!();
    }
    println!(
        "\nexpected shape: radar tracks vanilla at every depth; streaming\n\
         collapses once depth-back exceeds window+budget (~64); h2o between."
    );
    Ok(())
}
