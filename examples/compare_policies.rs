//! Compare every serving policy on the same teacher-forced stream:
//! quality (PPL) vs decode cost, the trade-off at the heart of the
//! paper. Prints one row per policy.
//!
//!   cargo run --release --offline --example compare_policies

use radar_serve::config::{ArtifactPaths, PolicyKind, ServingConfig};
use radar_serve::engine::{Engine, GenRequest};
use radar_serve::model::tokenizer;
use radar_serve::runtime::Runtime;
use radar_serve::workload::load_corpus;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let paths = ArtifactPaths::new("artifacts", "sm");
    let rt = Arc::new(Runtime::load(paths.clone())?);
    let corpus = load_corpus(&paths, "book_eval.bin")?;
    let prefill = 512usize;
    let eval_len = 1024usize;
    let toks = tokenizer::encode_bytes(&corpus[..eval_len]);

    println!(
        "teacher-forced evaluation: prefill {prefill}, evaluate {} tokens",
        eval_len - prefill
    );
    println!(
        "{:<14} {:>9} {:>12} {:>12} {:>10}",
        "policy", "PPL", "decode ms", "ms/token", "tokens"
    );
    for &policy in PolicyKind::all() {
        let mut cfg = ServingConfig::default();
        cfg.policy = policy;
        cfg.window = 64;
        cfg.budget = 128;
        let mut engine = Engine::new(rt.clone(), cfg)?;
        let req = GenRequest::teacher_forced(
            toks[..prefill].to_vec(),
            toks[prefill..].to_vec(),
        );
        let id = engine.add(req)?;
        let results = engine.run_to_completion()?;
        let res = results.into_iter().find(|r| r.id == id).unwrap();
        println!(
            "{:<14} {:>9.3} {:>12.1} {:>12.2} {:>10}",
            policy.name(),
            res.ppl(),
            res.decode_ms,
            res.decode_ms / res.logprobs.len() as f64,
            res.logprobs.len(),
        );
    }
    println!("\nexpected shape: vanilla = best PPL / slowest per token at length;");
    println!("streaming = fast / worst PPL; radar = near-vanilla PPL, sublinear cost.");
    Ok(())
}
