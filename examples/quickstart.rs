//! Quickstart: load a model, serve one request with Radar, print the
//! completion and timing. Run after `make artifacts`:
//!
//!   cargo run --release --offline --example quickstart

use radar_serve::config::{ArtifactPaths, PolicyKind, ServingConfig};
use radar_serve::engine::{Engine, GenRequest};
use radar_serve::model::tokenizer;
use radar_serve::runtime::Runtime;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    // 1. Load the AOT artifact set (HLO text + weights) onto PJRT CPU.
    let rt = Arc::new(Runtime::load(ArtifactPaths::new("artifacts", "sm"))?);

    // 2. Configure serving with the paper's method.
    let mut cfg = ServingConfig::default();
    cfg.policy = PolicyKind::Radar; // top-k segment retrieval (Alg. 1)
    cfg.radar_k = 8;                // segments per query
    let mut engine = Engine::new(rt, cfg)?;

    // 3. Serve a request.
    let prompt = "the stream carries old light towards dawn. ";
    let id = engine.add(GenRequest::new(tokenizer::encode(prompt), 48))?;
    let results = engine.run_to_completion()?;
    let res = results.into_iter().find(|r| r.id == id).unwrap();

    println!("prompt: {prompt}");
    println!("completion: {}", tokenizer::decode(&res.tokens));
    println!(
        "{} tokens | prefill {:.1} ms | decode {:.1} ms | {:.0} tok/s",
        res.logprobs.len(),
        res.prefill_ms,
        res.decode_ms,
        res.logprobs.len() as f64 / (res.decode_ms / 1e3).max(1e-9),
    );
    Ok(())
}
