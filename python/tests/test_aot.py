"""AOT path smoke tests: lowering produces loadable HLO text with the
expected entry layout, and golden vectors have the documented shapes."""

import re

import numpy as np
import pytest

from compile import model as M
from compile.aot import lower_decode, lower_prefill, make_golden

CFG = M.CONFIGS["sm"]


@pytest.fixture(scope="module")
def decode_hlo():
    return lower_decode(CFG, 1, 128, CFG.n_feat)


def test_decode_hlo_structure(decode_hlo):
    assert decode_hlo.startswith("HloModule")
    assert "ENTRY" in decode_hlo
    layout = decode_hlo.splitlines()[0]
    # 34 weight tensors + omega + 5 runtime inputs, 5-tuple output
    n_weights = len(M.tensor_manifest(CFG))
    assert layout.count("f32[") >= n_weights + 5
    assert "s32[1]" in layout                   # tokens/pos
    assert "f32[1,4,2,128,64]" in layout        # K/V bucket
    assert "f32[1,256]" in layout               # logits [B, V]
    assert "f32[1,4,2,129]" in layout           # probs S+1


def test_decode_hlo_no_custom_calls(decode_hlo):
    """interpret=True must lower to plain HLO (no Mosaic custom-calls,
    which the CPU PJRT client cannot execute)."""
    assert "custom-call" not in decode_hlo or "mosaic" not in decode_hlo.lower()


def test_prefill_hlo_structure():
    text = lower_prefill(CFG, 128, 256, CFG.n_feat)
    layout = text.splitlines()[0]
    assert "s32[128]" in layout                 # chunk tokens
    assert "f32[4,2,256,64]" in layout          # past KV bucket
    assert "f32[4,2,384]" in layout             # colsum P+T


def test_prefill_p0_lowerable():
    text = lower_prefill(CFG, 128, 0, CFG.n_feat)
    assert "ENTRY" in text


def test_golden_shapes():
    params = M.init_params(CFG, seed=0)
    omega = M.make_omega(CFG, CFG.n_feat)
    g = make_golden(CFG, params, omega)
    L, H, dh, n = CFG.n_layers, CFG.n_heads, CFG.d_head, CFG.n_feat
    assert g["dec_out_logits"].shape == (1, 256)
    assert g["dec_out_k_new"].shape == (1, L, H, dh)
    assert g["dec_out_feat_new"].shape == (1, L, H, n)
    assert g["dec_out_probs"].shape == (1, L, H, 129)
    assert g["pre_out_logits"].shape == (128, 256)
    assert g["pre_out_colsum"].shape == (L, H, 384)
    assert np.isfinite(g["dec_out_logits"]).all()
    assert np.isfinite(g["pre_out_logits"]).all()
