"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes; fixed cases cover the edges (all-padded cache,
single block, multiple blocks, zero-length past).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import (
    phi_ref, segment_mean_ref, attend_decode_ref, attend_prefill_ref,
)
from compile.kernels.phi import phi_pallas, BLOCK_M
from compile.kernels.attend import (
    attend_decode_pallas, attend_prefill_pallas, BLOCK_S,
)

RTOL, ATOL = 1e-4, 1e-5


def _rand(rng, *shape, scale=1.0):
    return jnp.asarray(rng.randn(*shape).astype(np.float32) * scale)


# ---------------------------------------------------------------------------
# phi (Eq. 4)
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 300),
    d=st.sampled_from([16, 32, 64]),
    n=st.sampled_from([32, 64, 128]),
    seed=st.integers(0, 10_000),
)
def test_phi_matches_ref(m, d, n, seed):
    rng = np.random.RandomState(seed)
    k = _rand(rng, m, d, scale=0.5)
    omega = _rand(rng, n, d)
    np.testing.assert_allclose(
        phi_pallas(k, omega), phi_ref(k, omega), rtol=RTOL, atol=ATOL
    )


def test_phi_nonnegative_and_scaled():
    rng = np.random.RandomState(0)
    k, omega = _rand(rng, 64, 64, scale=0.3), _rand(rng, 128, 64)
    f = np.asarray(phi_pallas(k, omega))
    assert (f >= 0).all(), "Eq.4 features must be positive"


def test_phi_kernel_estimates_softmax_kernel():
    """Lemma 1: E[phi(q).phi(k)] = exp(q.k/sqrt(d)). Check the Monte-Carlo
    estimate converges for a large n."""
    rng = np.random.RandomState(1)
    d, n = 32, 8192
    q, k = _rand(rng, 1, d, scale=0.4), _rand(rng, 1, d, scale=0.4)
    omega = _rand(rng, n, d)
    est = float((phi_ref(q, omega) @ phi_ref(k, omega).T).reshape(()))
    exact = float(np.exp(np.asarray(q) @ np.asarray(k).T / np.sqrt(d)).reshape(()))
    assert abs(est - exact) / exact < 0.15, (est, exact)


def test_phi_block_boundary():
    """M exactly at and one over the BLOCK_M boundary."""
    rng = np.random.RandomState(2)
    omega = _rand(rng, 64, 32)
    for m in (BLOCK_M, BLOCK_M + 1, 2 * BLOCK_M):
        k = _rand(rng, m, 32, scale=0.5)
        np.testing.assert_allclose(
            phi_pallas(k, omega), phi_ref(k, omega), rtol=RTOL, atol=ATOL
        )


def test_segment_mean_ref_shape():
    rng = np.random.RandomState(3)
    f = _rand(rng, 12, 8)
    s = segment_mean_ref(f, 4)
    assert s.shape == (3, 8)
    np.testing.assert_allclose(s[0], f[:4].mean(axis=0), rtol=1e-6)


# ---------------------------------------------------------------------------
# decode attend
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(
    g=st.integers(1, 6),
    nblocks=st.integers(1, 4),
    valid=st.integers(0, 100),
    seed=st.integers(0, 10_000),
)
def test_attend_decode_matches_ref(g, nblocks, valid, seed):
    rng = np.random.RandomState(seed)
    s_len, d = nblocks * BLOCK_S, 64
    q, ks, vs = _rand(rng, g, d), _rand(rng, g, d), _rand(rng, g, d)
    K, V = _rand(rng, g, s_len, d), _rand(rng, g, s_len, d)
    mask = np.zeros((g, s_len), np.float32)
    mask[:, min(valid, s_len):] = -1e30
    mask = jnp.asarray(mask)
    o1, p1 = attend_decode_pallas(q, K, V, ks, vs, mask)
    o2, p2 = attend_decode_ref(q, K, V, ks, vs, mask)
    np.testing.assert_allclose(o1, o2, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(p1, p2, rtol=RTOL, atol=ATOL)


def test_attend_decode_all_padded_is_self_attention():
    """Fully-masked cache => output == v_self, probs = one-hot on self."""
    rng = np.random.RandomState(4)
    g, s_len, d = 2, BLOCK_S, 64
    q, ks, vs = _rand(rng, g, d), _rand(rng, g, d), _rand(rng, g, d)
    K, V = _rand(rng, g, s_len, d), _rand(rng, g, s_len, d)
    mask = jnp.full((g, s_len), -1e30)
    o, p = attend_decode_pallas(q, K, V, ks, vs, mask)
    np.testing.assert_allclose(o, vs, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(p)[:, -1], 1.0, rtol=1e-5)


def test_attend_decode_probs_normalized():
    rng = np.random.RandomState(5)
    g, s_len, d = 3, 2 * BLOCK_S, 64
    q, ks, vs = _rand(rng, g, d), _rand(rng, g, d), _rand(rng, g, d)
    K, V = _rand(rng, g, s_len, d), _rand(rng, g, s_len, d)
    mask = jnp.zeros((g, s_len))
    _, p = attend_decode_pallas(q, K, V, ks, vs, mask)
    np.testing.assert_allclose(np.asarray(p).sum(-1), 1.0, rtol=1e-5)


# ---------------------------------------------------------------------------
# prefill attend
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(
    g=st.integers(1, 4),
    p_blocks=st.integers(0, 3),
    valid=st.integers(0, 200),
    seed=st.integers(0, 10_000),
)
def test_attend_prefill_matches_ref(g, p_blocks, valid, seed):
    rng = np.random.RandomState(seed)
    t_len, d = 128, 64
    p_len = p_blocks * BLOCK_S
    q = _rand(rng, g, t_len, d)
    kp, vp = _rand(rng, g, p_len, d), _rand(rng, g, p_len, d)
    kc, vc = _rand(rng, g, t_len, d), _rand(rng, g, t_len, d)
    pm = np.zeros((g, p_len), np.float32)
    pm[:, min(valid, p_len):] = -1e30
    pm = jnp.asarray(pm)
    o1, c1 = attend_prefill_pallas(q, kp, vp, kc, vc, pm)
    o2, c2 = attend_prefill_ref(q, kp, vp, kc, vc, pm)
    np.testing.assert_allclose(o1, o2, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(c1, c2, rtol=RTOL, atol=ATOL)


def test_attend_prefill_causality():
    """Changing a later chunk token must not affect earlier outputs."""
    rng = np.random.RandomState(6)
    g, t_len, d = 1, 128, 64
    q = _rand(rng, g, t_len, d)
    kc, vc = _rand(rng, g, t_len, d), _rand(rng, g, t_len, d)
    empty = jnp.zeros((g, 0, d))
    pm = jnp.zeros((g, 0))
    o1, _ = attend_prefill_pallas(q, empty, empty, kc, vc, pm)
    kc2 = kc.at[:, -1].set(99.0)
    vc2 = vc.at[:, -1].set(99.0)
    o2, _ = attend_prefill_pallas(q, empty, empty, kc2, vc2, pm)
    np.testing.assert_allclose(o1[:, :-1], o2[:, :-1], rtol=1e-5, atol=1e-6)
    assert not np.allclose(o1[:, -1], o2[:, -1])


def test_attend_prefill_colsum_total_mass():
    """Column sums over all keys must total T (each query row sums to 1)."""
    rng = np.random.RandomState(7)
    g, t_len, p_len, d = 2, 128, 128, 64
    q = _rand(rng, g, t_len, d)
    kp, vp = _rand(rng, g, p_len, d), _rand(rng, g, p_len, d)
    kc, vc = _rand(rng, g, t_len, d), _rand(rng, g, t_len, d)
    pm = jnp.zeros((g, p_len))
    _, cs = attend_prefill_pallas(q, kp, vp, kc, vc, pm)
    np.testing.assert_allclose(np.asarray(cs).sum(-1), t_len, rtol=1e-4)
