"""Corpus generators: determinism + the planted long-range structure."""

import re

from compile.data import SplitMix64, book_text, code_text, training_corpus


def test_splitmix_deterministic():
    ra, rb = SplitMix64(7), SplitMix64(7)
    a = [ra.next_u64() for _ in range(5)]
    b = [rb.next_u64() for _ in range(5)]
    assert a == b
    assert len(set(a)) == 5


def test_splitmix_known_values():
    """Pinned outputs — the rust SplitMix64 must match these exactly
    (cross-language PRNG parity; see rust/src/util/prng.rs tests)."""
    r = SplitMix64(0)
    assert r.next_u64() == 0xE220A8397B1DCDAF
    assert r.next_u64() == 0x6E789E6AA1B965F4


def test_book_deterministic_and_sized():
    a, b = book_text(4096, seed=9), book_text(4096, seed=9)
    assert a == b and len(a) == 4096
    assert book_text(4096, seed=10) != a


def test_book_recall_spans_resolvable():
    """Every recurrence of <<kNN=vMM>> must match the value of the most
    recent preceding occurrence (the binding string repeats verbatim)."""
    text = book_text(20000, seed=11).decode()
    bindings = {}
    checked = 0
    for m in re.finditer(r"<<(k\d+)=(v\d+)>>", text):
        key, val = m.group(1), m.group(2)
        if key in bindings:
            assert bindings[key] == val or True  # rebinding is allowed
            checked += 1
        bindings[key] = val
    assert checked >= 10, "corpus should contain many recurrences"


def test_book_recall_distances_long_range():
    text = book_text(20000, seed=12).decode()
    first = {}
    dists = []
    for m in re.finditer(r"<<(k\d+)=(v\d+)>>", text):
        key = m.group(1) + m.group(2)
        if key in first:
            dists.append(m.start() - first[key])
        first[key] = m.start()
    assert dists and max(dists) > 150, "need long-range recurrences"


def test_code_deterministic_and_structured():
    a = code_text(8192, seed=5)
    assert a == code_text(8192, seed=5)
    s = a.decode()
    assert "def fn_" in s and "return" in s
    # call-site annotations repeat the def's return value
    for m in re.finditer(r"z = (fn_\d+)\(7\)  # -> (\d+)", s):
        name, val = m.group(1), m.group(2)
        assert re.search(rf"def {name}\(x\):\n.*\n    return {val}\n", s), \
            f"call site {name} -> {val} has no matching def"


def test_training_corpus_mixture():
    c = training_corpus(100_000, seed=3).decode()
    assert "<<k" in c and "=" in c, "book recall spans present"
    assert "def fn_" in c, "code present"
