"""L2 correctness: model graphs compose consistently.

The critical invariant: a full-sequence causal forward must equal
(prefill chunks) + (decode steps against the accumulated KV) — that is
what proves rust's incremental serving math equals the oracle model.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model as M

CFG = M.CONFIGS["sm"]


@pytest.fixture(scope="module")
def setup():
    params = M.init_params(CFG, seed=0)
    weights = [params[n] for n, _ in M.tensor_manifest(CFG)]
    omega = jnp.asarray(M.make_omega(CFG, CFG.n_feat))
    return params, weights, omega


def _prefill(weights, omega, toks, P, pastK, pastV, pmask, pos0):
    fn = M.prefill_fn(CFG, len(toks), P, use_pallas=True)
    return fn(*weights, omega, jnp.asarray(toks, jnp.int32),
              jnp.int32(pos0), pastK, pastV, pmask)


def test_manifest_roundtrip():
    params = M.init_params(CFG, seed=3)
    flat = M.params_to_flat(params, CFG)
    back = M.flat_to_params(flat, CFG)
    for k in params:
        np.testing.assert_array_equal(np.asarray(params[k]),
                                      np.asarray(back[k]))


def test_manifest_covers_all_params():
    params = M.init_params(CFG, seed=0)
    names = {n for n, _ in M.tensor_manifest(CFG)}
    assert names == set(params.keys())


def test_rope_preserves_norm():
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(5, 64).astype(np.float32))
    pos = jnp.asarray([0, 1, 7, 100, 1000])
    y = M.rope(x, pos, CFG.rope_theta)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-5,
    )


def test_rope_relative_property():
    """q(pos a).k(pos b) depends only on a-b (per frequency pair)."""
    rng = np.random.RandomState(1)
    q = jnp.asarray(rng.randn(1, 64).astype(np.float32))
    k = jnp.asarray(rng.randn(1, 64).astype(np.float32))
    def dot(a, b):
        qa = M.rope(q, jnp.asarray([a]), CFG.rope_theta)
        kb = M.rope(k, jnp.asarray([b]), CFG.rope_theta)
        return float(jnp.sum(qa * kb))
    assert abs(dot(10, 3) - dot(107, 100)) < 1e-3
    assert abs(dot(10, 3) - dot(10, 4)) > 1e-6   # but not position-blind


def test_prefill_p0_equals_full_forward(setup):
    params, weights, omega = setup
    rng = np.random.RandomState(0)
    toks = rng.randint(0, 255, 128)
    full = M.forward(params, CFG, jnp.asarray(toks[None], jnp.int32))[0]
    L, H, dh = CFG.n_layers, CFG.n_heads, CFG.d_head
    outs = _prefill(weights, omega, toks, 0,
                    jnp.zeros((L, H, 0, dh)), jnp.zeros((L, H, 0, dh)),
                    jnp.zeros((0,)), 0)
    np.testing.assert_allclose(outs[0], full, rtol=1e-4, atol=1e-4)


def test_chunked_prefill_equals_full_forward(setup):
    """Two 128-token chunks == one 256-token causal forward."""
    params, weights, omega = setup
    rng = np.random.RandomState(2)
    toks = rng.randint(0, 255, 256)
    full = M.forward(params, CFG, jnp.asarray(toks[None], jnp.int32))[0]
    L, H, dh = CFG.n_layers, CFG.n_heads, CFG.d_head
    o1 = _prefill(weights, omega, toks[:128], 0,
                  jnp.zeros((L, H, 0, dh)), jnp.zeros((L, H, 0, dh)),
                  jnp.zeros((0,)), 0)
    # Pad chunk-1 KV into the P=256 bucket.
    P = 256
    pastK = jnp.zeros((L, H, P, dh)).at[:, :, :128].set(o1[1])
    pastV = jnp.zeros((L, H, P, dh)).at[:, :, :128].set(o1[2])
    pmask = jnp.zeros((P,)).at[128:].set(-1e30)
    o2 = _prefill(weights, omega, toks[128:], P, pastK, pastV, pmask, 128)
    got = jnp.concatenate([o1[0], o2[0]])
    np.testing.assert_allclose(got, full, rtol=1e-4, atol=1e-4)


def test_decode_equals_full_forward(setup):
    """Prefill 128 then decode 3 tokens one-by-one == full forward."""
    params, weights, omega = setup
    rng = np.random.RandomState(3)
    toks = rng.randint(0, 255, 131)
    full = M.forward(params, CFG, jnp.asarray(toks[None], jnp.int32))[0]
    L, H, dh = CFG.n_layers, CFG.n_heads, CFG.d_head
    o1 = _prefill(weights, omega, toks[:128], 0,
                  jnp.zeros((L, H, 0, dh)), jnp.zeros((L, H, 0, dh)),
                  jnp.zeros((0,)), 0)
    S = 256
    K = np.zeros((1, L, H, S, dh), np.float32)
    V = np.zeros((1, L, H, S, dh), np.float32)
    K[0, :, :, :128] = np.asarray(o1[1])
    V[0, :, :, :128] = np.asarray(o1[2])
    dec = M.decode_step_fn(CFG, 1, S, use_pallas=True)
    for i, t in enumerate(range(128, 131)):
        mask = np.zeros((1, L, H, S), np.float32)
        mask[..., t:] = -1e30
        outs = dec(*weights, omega,
                   jnp.asarray([toks[t]], jnp.int32),
                   jnp.asarray([t], jnp.int32),
                   jnp.asarray(K), jnp.asarray(V), jnp.asarray(mask))
        np.testing.assert_allclose(
            outs[0][0], full[t], rtol=2e-4, atol=2e-4,
            err_msg=f"logits diverge at decode step {i}",
        )
        K[0, :, :, t] = np.asarray(outs[1][0])
        V[0, :, :, t] = np.asarray(outs[2][0])


def test_decode_feat_matches_phi_of_knew(setup):
    from compile.kernels.ref import phi_ref
    params, weights, omega = setup
    L, H, dh, S = CFG.n_layers, CFG.n_heads, CFG.d_head, 128
    dec = M.decode_step_fn(CFG, 1, S, use_pallas=True)
    outs = dec(*weights, omega,
               jnp.asarray([65], jnp.int32), jnp.asarray([0], jnp.int32),
               jnp.zeros((1, L, H, S, dh)), jnp.zeros((1, L, H, S, dh)),
               jnp.full((1, L, H, S), -1e30))
    k_new, feat = outs[1][0], outs[3][0]          # [L,H,dh], [L,H,n]
    want = phi_ref(k_new.reshape(-1, dh), omega).reshape(L, H, -1)
    np.testing.assert_allclose(feat, want, rtol=1e-4, atol=1e-5)


def test_decode_probs_sum_to_one(setup):
    params, weights, omega = setup
    L, H, dh, S = CFG.n_layers, CFG.n_heads, CFG.d_head, 128
    rng = np.random.RandomState(5)
    K = jnp.asarray(rng.randn(1, L, H, S, dh).astype(np.float32) * 0.3)
    V = jnp.asarray(rng.randn(1, L, H, S, dh).astype(np.float32) * 0.3)
    dec = M.decode_step_fn(CFG, 1, S, use_pallas=True)
    outs = dec(*weights, omega,
               jnp.asarray([7], jnp.int32), jnp.asarray([50], jnp.int32),
               K, V, jnp.zeros((1, L, H, S)).at[..., 50:].set(-1e30))
    np.testing.assert_allclose(np.asarray(outs[4]).sum(-1), 1.0, rtol=1e-4)


def test_batched_decode_rows_independent(setup):
    """B=2 decode == two B=1 decodes (batching must not mix rows)."""
    params, weights, omega = setup
    L, H, dh, S = CFG.n_layers, CFG.n_heads, CFG.d_head, 128
    rng = np.random.RandomState(6)
    K = rng.randn(2, L, H, S, dh).astype(np.float32) * 0.3
    V = rng.randn(2, L, H, S, dh).astype(np.float32) * 0.3
    mask = np.zeros((2, L, H, S), np.float32)
    mask[0, ..., 30:] = -1e30
    mask[1, ..., 90:] = -1e30
    toks = np.array([10, 200], np.int32)
    pos = np.array([30, 90], np.int32)
    dec2 = M.decode_step_fn(CFG, 2, S, use_pallas=True)
    out2 = dec2(*weights, omega, jnp.asarray(toks), jnp.asarray(pos),
                jnp.asarray(K), jnp.asarray(V), jnp.asarray(mask))
    dec1 = M.decode_step_fn(CFG, 1, S, use_pallas=True)
    for b in range(2):
        out1 = dec1(*weights, omega,
                    jnp.asarray(toks[b:b+1]), jnp.asarray(pos[b:b+1]),
                    jnp.asarray(K[b:b+1]), jnp.asarray(V[b:b+1]),
                    jnp.asarray(mask[b:b+1]))
        np.testing.assert_allclose(out2[0][b], out1[0][0],
                                   rtol=1e-4, atol=1e-4)
