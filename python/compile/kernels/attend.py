"""Pallas attention kernels for the Radar serving hot path.

Two kernels:

- ``attend_decode_pallas`` — the per-token decode hot-spot: one query
  attends to the S gathered cache tokens (padded, additive mask) plus
  the current token's own K/V. Implemented as a **two-pass streaming
  softmax** over BLOCK_S key blocks (pass 1: running max + normalizer;
  pass 2: probabilities, weighted values). The blocked structure is the
  FlashAttention-style schedule the paper's §Related-Work cites as
  orthogonal/composable; on a TPU each BLOCK_S x d tile streams
  HBM->VMEM while the MXU consumes the previous one.

- ``attend_prefill_pallas`` — chunked prefill: T=128 chunk queries
  attend to P past tokens (mask-padded) + causally to the chunk. Also
  emits per-key column sums of the normalized probabilities (the
  H2O / SnapKV importance signal).

VMEM estimate, decode kernel (f32): q d + 2*BLOCK_S*d (K,V tiles)
+ BLOCK_S probs = 64 + 2*128*64 + 128 ≈ 16.6k floats ≈ 65 KiB.
Prefill kernel: T*d q + 2*BLOCK_S*d + T*BLOCK_S scores tile ≈
8k + 16k + 16k floats ≈ 160 KiB. Both leave >98% of VMEM for
double-buffering; arithmetic intensity ≈ 2 flops/byte => the kernels
are HBM-bandwidth-bound and the one-pass-per-tile structure is at
roofline by construction.

interpret=True is mandatory on this box (CPU PJRT); the program is
unchanged for a real TPU lowering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_S = 128
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Decode: single query vs gathered cache
# ---------------------------------------------------------------------------

def _attend_decode_kernel(
    q_ref, k_ref, v_ref, ks_ref, vs_ref, mask_ref, o_ref, p_ref,
    *, s_len: int, d: int,
):
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    q = q_ref[0]                      # [d]
    k_self = ks_ref[0]                # [d]
    v_self = vs_ref[0]                # [d]
    s_self = jnp.sum(q * k_self) * scale
    n_blocks = s_len // BLOCK_S

    def block_scores(i):
        kb = pl.load(k_ref, (0, pl.dslice(i * BLOCK_S, BLOCK_S), slice(None)))
        mb = pl.load(mask_ref, (0, pl.dslice(i * BLOCK_S, BLOCK_S)))
        return jnp.dot(kb, q) * scale + mb               # [BLOCK_S]

    # Pass 1: running max and normalizer (self token seeds the carry).
    def pass1(i, carry):
        m, l = carry
        s = block_scores(i)
        m_new = jnp.maximum(m, jnp.max(s))
        l = l * jnp.exp(m - m_new) + jnp.sum(jnp.exp(s - m_new))
        return m_new, l

    m, l = jax.lax.fori_loop(0, n_blocks, pass1, (s_self, jnp.float32(1.0)))

    # Pass 2: normalized probabilities + weighted values.
    def pass2(i, acc):
        s = block_scores(i)
        p = jnp.exp(s - m) / l                            # [BLOCK_S]
        pl.store(p_ref, (0, pl.dslice(i * BLOCK_S, BLOCK_S)), p)
        vb = pl.load(v_ref, (0, pl.dslice(i * BLOCK_S, BLOCK_S), slice(None)))
        return acc + jnp.dot(p, vb)

    p_self = jnp.exp(s_self - m) / l
    acc = jax.lax.fori_loop(0, n_blocks, pass2, p_self * v_self)
    pl.store(p_ref, (0, pl.dslice(s_len, 1)), p_self[None])
    o_ref[0, :] = acc


def attend_decode_pallas(q, keys, values, k_self, v_self, mask):
    """q,k_self,v_self: [G,d]; keys,values: [G,S,d]; mask: [G,S] additive.

    Returns (out [G,d], probs [G,S+1]). S must be a multiple of BLOCK_S.
    """
    g, s_len, d = keys.shape
    assert s_len % BLOCK_S == 0, f"S={s_len} not a multiple of {BLOCK_S}"
    out, probs = pl.pallas_call(
        functools.partial(_attend_decode_kernel, s_len=s_len, d=d),
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((1, s_len, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s_len, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((1, s_len), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((1, s_len + 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g, d), jnp.float32),
            jax.ShapeDtypeStruct((g, s_len + 1), jnp.float32),
        ],
        interpret=True,
    )(q, keys, values, k_self, v_self, mask)
    return out, probs


# ---------------------------------------------------------------------------
# Prefill: chunk queries vs past + causal chunk
# ---------------------------------------------------------------------------

def _attend_prefill_kernel(
    q_ref, kp_ref, vp_ref, kc_ref, vc_ref, pm_ref, o_ref, cs_ref,
    *, t_len: int, p_len: int, d: int,
):
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    q = q_ref[0]                                          # [T, d]
    kc = kc_ref[0]                                        # [T, d]
    # Scores over the concatenated key axis [P + T]; the chunk part
    # carries the causal mask. On TPU this [T, P+T] tile is further
    # split along the key axis into BLOCK_S strips (documented in the
    # module header); interpret mode materializes it directly.
    s_chunk = jnp.dot(q, kc.T) * scale                    # [T, T]
    rows = jax.lax.broadcasted_iota(jnp.int32, (t_len, t_len), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (t_len, t_len), 1)
    s_chunk = jnp.where(rows >= cols, s_chunk, NEG_INF)
    if p_len > 0:
        kp = kp_ref[0]                                    # [P, d]
        s_past = jnp.dot(q, kp.T) * scale + pm_ref[0][None, :]
        scores = jnp.concatenate([s_past, s_chunk], axis=1)
    else:
        scores = s_chunk
    m = jnp.max(scores, axis=1, keepdims=True)
    p = jnp.exp(scores - m)
    probs = p / jnp.sum(p, axis=1, keepdims=True)         # [T, P+T]
    if p_len > 0:
        vals = jnp.concatenate([vp_ref[0], vc_ref[0]], axis=0)
    else:
        vals = vc_ref[0]
    o_ref[0, :, :] = jnp.dot(probs, vals)
    cs_ref[0, :] = jnp.sum(probs, axis=0)


def attend_prefill_pallas(q, k_past, v_past, k_chunk, v_chunk, past_mask):
    """q: [G,T,d]; k_past/v_past: [G,P,d]; k_chunk/v_chunk: [G,T,d];
    past_mask: [G,P]. Returns (out [G,T,d], colsum [G,P+T])."""
    g, t_len, d = q.shape
    p_len = k_past.shape[1]
    in_specs = [
        pl.BlockSpec((1, t_len, d), lambda i: (i, 0, 0)),
        pl.BlockSpec((1, max(p_len, 1), d), lambda i: (i, 0, 0)),
        pl.BlockSpec((1, max(p_len, 1), d), lambda i: (i, 0, 0)),
        pl.BlockSpec((1, t_len, d), lambda i: (i, 0, 0)),
        pl.BlockSpec((1, t_len, d), lambda i: (i, 0, 0)),
        pl.BlockSpec((1, max(p_len, 1)), lambda i: (i, 0)),
    ]
    if p_len == 0:
        # Zero-width inputs upset BlockSpec; feed 1-wide dummies.
        k_past = jnp.zeros((g, 1, d), jnp.float32)
        v_past = jnp.zeros((g, 1, d), jnp.float32)
        past_mask = jnp.full((g, 1), NEG_INF, jnp.float32)
    out, colsum = pl.pallas_call(
        functools.partial(
            _attend_prefill_kernel, t_len=t_len, p_len=p_len, d=d
        ),
        grid=(g,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, t_len, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, p_len + t_len), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g, t_len, d), jnp.float32),
            jax.ShapeDtypeStruct((g, p_len + t_len), jnp.float32),
        ],
        interpret=True,
    )(q, k_past, v_past, k_chunk, v_chunk, past_mask)
    return out, colsum
