"""Pallas kernel: positive random features (Eq. 4).

Computes phi(k) for a batch of key vectors against the shared random
matrix Omega. The grid tiles the token axis; each program instance
handles one block of BLOCK_M tokens and the full feature width n
(n <= 256 here; on a real TPU n would additionally be tiled to the
128-lane VPU width — the BlockSpec already expresses the HBM->VMEM
schedule for the token axis, which is the long one).

VMEM footprint per instance (f32): BLOCK_M*d + n*d + BLOCK_M*n
= 128*64 + 256*64 + 128*256 ≈ 57k floats ≈ 224 KiB — comfortably inside
a TPU core's ~16 MiB VMEM, leaving room for double buffering.
MXU: the inner product k' @ Omega^T is a [128,64]x[64,n] matmul —
MXU-shaped (multiples of the 128x128 systolic tile after padding).

Must be lowered with interpret=True on this box (CPU PJRT cannot run
Mosaic custom-calls); the same program is the TPU kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_M = 128


def _phi_kernel(k_ref, omega_ref, o_ref, *, d: int, n: int):
    # k_ref: [BLOCK_M, d]; omega_ref: [n, d]; o_ref: [BLOCK_M, n]
    kp = k_ref[...] / jnp.sqrt(jnp.sqrt(jnp.float32(d)))
    proj = jnp.dot(kp, omega_ref[...].T)                      # [BM, n]
    sq = 0.5 * jnp.sum(kp * kp, axis=-1, keepdims=True)       # [BM, 1]
    o_ref[...] = jnp.exp(proj - sq) / jnp.sqrt(jnp.float32(n))


def phi_pallas(k: jnp.ndarray, omega: jnp.ndarray) -> jnp.ndarray:
    """k: [M, d] (M padded to BLOCK_M internally), omega: [n, d] -> [M, n]."""
    m, d = k.shape
    n = omega.shape[0]
    m_pad = (m + BLOCK_M - 1) // BLOCK_M * BLOCK_M
    k_padded = jnp.pad(k, ((0, m_pad - m), (0, 0))) if m_pad != m else k
    out = pl.pallas_call(
        functools.partial(_phi_kernel, d=d, n=n),
        grid=(m_pad // BLOCK_M,),
        in_specs=[
            pl.BlockSpec((BLOCK_M, d), lambda i: (i, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_M, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m_pad, n), jnp.float32),
        interpret=True,
    )(k_padded, omega)
    return out[:m]
