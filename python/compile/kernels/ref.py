"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: pytest (and hypothesis sweeps)
assert the Pallas kernels in ``phi.py`` / ``attend.py`` match these to
float32 tolerance. They are also used directly by the training forward
pass (the kernels' interpret-mode lowering is slower to trace/grad).
"""

from __future__ import annotations

import jax.numpy as jnp


def phi_ref(k: jnp.ndarray, omega: jnp.ndarray) -> jnp.ndarray:
    """Positive random features, Eq. 4 of the paper.

    phi(k)_i = (1/sqrt(n)) * exp(omega_i . k' - ||k'||^2 / 2),
    with k' = k / d^(1/4) (d = head dim) so that
    E[phi(q) . phi(k)] = exp(q.k / sqrt(d)) — the softmax kernel.

    k: [..., d]; omega: [n, d]  ->  [..., n]
    """
    d = k.shape[-1]
    kp = k / jnp.sqrt(jnp.sqrt(jnp.float32(d)))
    n = omega.shape[0]
    # exp() can overflow for adversarial inputs; the paper's Lemma 6
    # assumes bounded norms. We compute in f32 like the kernel.
    proj = kp @ omega.T                                   # [..., n]
    sq = 0.5 * jnp.sum(kp * kp, axis=-1, keepdims=True)   # [..., 1]
    return jnp.exp(proj - sq) / jnp.sqrt(jnp.float32(n))


def segment_mean_ref(feat: jnp.ndarray, c: int) -> jnp.ndarray:
    """Eq. 5: mean-pool per-token features into segment summaries.

    feat: [t, n] with t divisible by c  ->  [t//c, n]
    """
    t, n = feat.shape
    return feat.reshape(t // c, c, n).mean(axis=1)


def attend_decode_ref(
    q: jnp.ndarray,        # [G, d]      G = flattened batch*heads
    keys: jnp.ndarray,     # [G, S, d]   gathered (padded) cache keys
    values: jnp.ndarray,   # [G, S, d]
    k_self: jnp.ndarray,   # [G, d]      current token's key
    v_self: jnp.ndarray,   # [G, d]
    mask: jnp.ndarray,     # [G, S]      additive: 0 = keep, -inf = pad
):
    """Single-query attention over the gathered token set plus self.

    Returns (out [G, d], probs [G, S+1]); probs[:, S] is the self token.
    """
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    s_cache = jnp.einsum("gd,gsd->gs", q, keys) * scale + mask   # [G, S]
    s_self = jnp.sum(q * k_self, axis=-1, keepdims=True) * scale  # [G, 1]
    scores = jnp.concatenate([s_cache, s_self], axis=-1)          # [G, S+1]
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    z = jnp.sum(p, axis=-1, keepdims=True)
    probs = p / z
    vals = jnp.concatenate([values, v_self[:, None, :]], axis=1)  # [G,S+1,d]
    out = jnp.einsum("gs,gsd->gd", probs, vals)
    return out, probs


def attend_prefill_ref(
    q: jnp.ndarray,         # [G, T, d]   chunk queries
    k_past: jnp.ndarray,    # [G, P, d]
    v_past: jnp.ndarray,    # [G, P, d]
    k_chunk: jnp.ndarray,   # [G, T, d]
    v_chunk: jnp.ndarray,   # [G, T, d]
    past_mask: jnp.ndarray,  # [G, P]     additive
):
    """Chunked-prefill attention: each chunk query attends to all past
    tokens (mask-padded) plus causally to the chunk itself.

    Returns (out [G, T, d], colsum [G, P+T]) where colsum[j] is the total
    normalized attention mass received by key j across the T queries —
    the signal H2O / SnapKV consume.
    """
    G, T, d = q.shape
    P = k_past.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    keys = jnp.concatenate([k_past, k_chunk], axis=1)     # [G, P+T, d]
    vals = jnp.concatenate([v_past, v_chunk], axis=1)
    scores = jnp.einsum("gtd,gsd->gts", q, keys) * scale  # [G, T, P+T]
    # past mask (padding) + causal mask within the chunk
    causal = jnp.tril(jnp.ones((T, T), dtype=bool))
    full_mask = jnp.concatenate(
        [
            jnp.broadcast_to(past_mask[:, None, :], (G, T, P)),
            jnp.broadcast_to(jnp.where(causal, 0.0, -jnp.inf)[None], (G, T, T)),
        ],
        axis=-1,
    )
    scores = scores + full_mask
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    probs = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("gts,gsd->gtd", probs, vals)
    colsum = jnp.sum(probs, axis=1)                       # [G, P+T]
    return out, colsum
