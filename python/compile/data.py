"""Synthetic corpora with planted long-range dependencies.

The paper evaluates on PG-19 (books) and The Stack (code). Offline, we
substitute deterministic synthetic corpora that preserve the property the
experiments actually probe: *sparse, genuinely long-range attention*.

Two generators:

- ``book_text``  — pseudo-English prose from a seeded syllable Markov
  model, with planted key/value *recall spans*: a definition
  ``<<k17:v83>>`` appears, and 50-400 bytes later the probe ``<<k17?>>``
  must be answered with ``v83``. A trained model resolves the probe only
  by attending back to the definition — exactly the signal that
  eviction-based baselines (StreamingLLM/H2O/SnapKV) destroy and Radar's
  segment retrieval preserves.
- ``code_text``  — code-like text: function definitions with numeric
  bodies and later call sites that repeat the definition's result,
  plus nested bracket structure.

Everything is byte-level (vocab = 256) and reproducible from a seed.
"""

from __future__ import annotations

import argparse
import os


class SplitMix64:
    """Tiny deterministic PRNG (same algorithm as the rust side)."""

    MASK = (1 << 64) - 1

    def __init__(self, seed: int):
        self.state = seed & self.MASK

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & self.MASK
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & self.MASK
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & self.MASK
        return (z ^ (z >> 31)) & self.MASK

    def below(self, n: int) -> int:
        """Uniform integer in [0, n)."""
        return self.next_u64() % n

    def choice(self, seq):
        return seq[self.below(len(seq))]


# ---------------------------------------------------------------------------
# Pseudo-English prose
# ---------------------------------------------------------------------------

_ONSETS = ["b", "br", "c", "ch", "d", "dr", "f", "fl", "g", "gr", "h", "j",
           "k", "l", "m", "n", "p", "pl", "qu", "r", "s", "sh", "st", "t",
           "th", "tr", "v", "w"]
_NUCLEI = ["a", "e", "i", "o", "u", "ai", "ea", "ou", "oo"]
_CODAS = ["", "", "n", "r", "s", "t", "l", "m", "nd", "st", "ck", "sh"]


def _make_lexicon(rng: SplitMix64, n_words: int) -> list[str]:
    words = set()
    while len(words) < n_words:
        n_syll = 1 + rng.below(3)
        w = "".join(
            rng.choice(_ONSETS) + rng.choice(_NUCLEI) + rng.choice(_CODAS)
            for _ in range(n_syll)
        )
        if 2 <= len(w) <= 12:
            words.add(w)
    return sorted(words)


def recall_drills(n_bytes: int, seed: int = 5, n_keys: int = 64,
                  n_vals: int = 64, max_dist: int = 350) -> bytes:
    """Dense key/value recall practice: bindings followed by probes at
    controlled distances — the curriculum that teaches the induction
    behaviour the needle/LongBench-S evaluations probe."""
    rng = SplitMix64(seed)
    out = bytearray()
    live: list[tuple[str, str]] = []
    fill_words = ["so", "then", "and", "yet", "while", "for"]
    while len(out) < n_bytes:
        r = rng.below(10)
        if r < 4 or not live:
            k = f"k{rng.below(n_keys):02d}"
            v = f"v{rng.below(n_vals):02d}"
            out += f"<<{k}={v}>> ".encode()
            live.append((k, v))
            if len(live) > 6:
                live.pop(0)
        elif r < 8:
            k, v = live[rng.below(len(live))]
            out += f"<<{k}={v}>> ".encode()
        else:
            for _ in range(rng.below(max_dist // 8) + 2):
                out += fill_words[rng.below(6)].encode() + b" "
    return bytes(out[:n_bytes])


def book_text(
    n_bytes: int,
    seed: int = 7,
    recall_every: int = 100,
    recall_min_dist: int = 40,
    recall_max_dist: int = 350,
    n_keys: int = 64,
    n_vals: int = 64,
) -> bytes:
    """Prose with planted ``<<kNN:vMM>> ... <<kNN?>>vMM`` recall spans."""
    rng = SplitMix64(seed)
    lex = _make_lexicon(rng, 400)
    # Bigram chain over the lexicon: each word gets a small successor set,
    # giving locally coherent (learnable) statistics.
    succ = {
        w: [rng.choice(lex) for _ in range(4)]
        for w in lex
    }
    out = bytearray()
    pending: list[tuple[int, str, str]] = []  # (emit_at, key, val)
    word = rng.choice(lex)
    sent_len = 0
    since_recall = 0
    while len(out) < n_bytes:
        # Emit any due probe spans: the binding string recurs VERBATIM
        # ("<<k17=v83>>"), so resolving the value is an exact-prefix
        # induction (attend to the previous occurrence, copy).
        while pending and pending[0][0] <= len(out):
            _, k, v = pending.pop(0)
            out += f"<<{k}={v}>> ".encode()
        if since_recall >= recall_every and len(pending) < 8:
            # Never rebind a key with an outstanding probe: probes must be
            # resolvable from the *most recent* preceding definition.
            busy = {k for _, k, _ in pending}
            k = f"k{rng.below(n_keys):02d}"
            while k in busy:
                k = f"k{rng.below(n_keys):02d}"
            v = f"v{rng.below(n_vals):02d}"
            out += f"<<{k}={v}>> ".encode()
            dist = recall_min_dist + rng.below(recall_max_dist - recall_min_dist)
            pending.append((len(out) + dist, k, v))
            pending.sort()
            since_recall = 0
            continue
        w = word
        out += w.encode()
        sent_len += len(w) + 1
        since_recall += len(w) + 1
        if sent_len > 40 + rng.below(40):
            out += b". "
            word = rng.choice(lex)
            sent_len = 0
        else:
            out += b" "
            word = rng.choice(succ[w])
    return bytes(out[:n_bytes])


# ---------------------------------------------------------------------------
# Code-like text
# ---------------------------------------------------------------------------

def code_text(n_bytes: int, seed: int = 13) -> bytes:
    """Code-like corpus: defs bind names to constants; later call sites
    must reproduce the bound constant (long-range symbol resolution)."""
    rng = SplitMix64(seed)
    out = bytearray()
    defs: list[tuple[str, int]] = []
    while len(out) < n_bytes:
        r = rng.below(10)
        if r < 3 or not defs:
            name = f"fn_{rng.below(90):02d}"
            val = rng.below(90)
            body = " + ".join(str(rng.below(9)) for _ in range(1 + rng.below(3)))
            out += f"def {name}(x):\n    y = {body}\n    return {val}\n".encode()
            defs.append((name, val))
            if len(defs) > 24:
                defs.pop(0)
        elif r < 7:
            # Call site: the "comment" repeats the def's return value —
            # resolvable only by attending back to the definition.
            name, val = defs[rng.below(len(defs))]
            out += f"z = {name}(7)  # -> {val}\n".encode()
        else:
            depth = 1 + rng.below(4)
            inner = str(rng.below(100))
            expr = "[" * depth + inner + "]" * depth
            out += f"lst = {expr}\n".encode()
    return bytes(out[:n_bytes])


# ---------------------------------------------------------------------------
# Training stream
# ---------------------------------------------------------------------------

def training_corpus(n_bytes: int, seed: int = 3) -> bytes:
    """Mixture used for LM training: 50% book, 20% code, 30% recall
    drills, interleaved in 2 KiB chunks so every style appears within
    every training window. The drill share is what makes the tiny model
    learn the induction/copy behaviour the serving evaluations probe."""
    book = book_text(int(n_bytes * 0.5) + 4096, seed=seed)
    code = code_text(int(n_bytes * 0.2) + 4096, seed=seed + 1)
    drill = recall_drills(int(n_bytes * 0.3) + 4096, seed=seed + 4)
    out = bytearray()
    bi = ci = di = 0
    chunk = 2048
    rng = SplitMix64(seed + 2)
    while len(out) < n_bytes:
        r = rng.below(10)
        if r < 5:
            out += book[bi : bi + chunk]
            bi += chunk
        elif r < 7:
            out += code[ci : ci + chunk]
            ci += chunk
        else:
            out += drill[di : di + chunk]
            di += chunk
    return bytes(out[:n_bytes])


def main() -> None:
    ap = argparse.ArgumentParser(description="Dump evaluation corpora")
    ap.add_argument("--out", default="../artifacts/corpus")
    ap.add_argument("--book-bytes", type=int, default=16384)
    ap.add_argument("--code-bytes", type=int, default=16384)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    with open(os.path.join(args.out, "book_eval.bin"), "wb") as f:
        f.write(book_text(args.book_bytes, seed=101))
    with open(os.path.join(args.out, "code_eval.bin"), "wb") as f:
        f.write(code_text(args.code_bytes, seed=102))
    print(f"wrote corpora to {args.out}")


if __name__ == "__main__":
    main()
