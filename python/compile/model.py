"""L2: the transformer decoder served by the rust coordinator.

Byte-level decoder LM: RMSNorm -> attention (RoPE) -> residual ->
RMSNorm -> GELU MLP -> residual; tied input/output embeddings.

Three entry points:

- ``forward``          — full-sequence causal forward (pure jnp), used
                         for training and as the end-to-end oracle.
- ``decode_step_fn``   — one token per sequence against a *gathered*
                         (policy-selected, padded) KV buffer. This is
                         the serving hot path; it calls the Pallas
                         kernels and is AOT-lowered per (B, S) bucket.
- ``prefill_fn``       — one 128-token chunk against past KV, lowered
                         per past-length bucket.

The weight layout (``tensor_manifest``) is the ABI shared with rust:
rust reads ``weights.bin`` + ``manifest.json`` and uploads each tensor
as a device-resident PJRT buffer in exactly this order.
"""

from __future__ import annotations

from dataclasses import dataclass, asdict

import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels.phi import phi_pallas
from compile.kernels.attend import attend_decode_pallas, attend_prefill_pallas
from compile.kernels import ref as kref

VOCAB = 256


@dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_layers: int
    n_heads: int
    d_head: int
    d_ffn: int
    n_feat: int          # default random-feature dim n (Omega rows)
    max_train_len: int   # "pre-training context length" for the paper's plots
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def d_attn(self) -> int:
        return self.n_heads * self.d_head


CONFIGS = {
    # "sm" plays the paper's Llama role; "md" the Mistral role (bigger,
    # relatively under-trained, collapses past its native context).
    "sm": ModelConfig("sm", d_model=128, n_layers=4, n_heads=2, d_head=64,
                      d_ffn=512, n_feat=128, max_train_len=512),
    "md": ModelConfig("md", d_model=256, n_layers=4, n_heads=4, d_head=64,
                      d_ffn=1024, n_feat=128, max_train_len=512),
}


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def tensor_manifest(cfg: ModelConfig) -> list[tuple[str, tuple[int, ...]]]:
    """Flat (name, shape) list — the rust<->python weight ABI."""
    out: list[tuple[str, tuple[int, ...]]] = []
    for l in range(cfg.n_layers):
        out += [
            (f"layers.{l}.wq", (cfg.d_model, cfg.d_attn)),
            (f"layers.{l}.wk", (cfg.d_model, cfg.d_attn)),
            (f"layers.{l}.wv", (cfg.d_model, cfg.d_attn)),
            (f"layers.{l}.wo", (cfg.d_attn, cfg.d_model)),
            (f"layers.{l}.w1", (cfg.d_model, cfg.d_ffn)),
            (f"layers.{l}.w2", (cfg.d_ffn, cfg.d_model)),
            (f"layers.{l}.ln1", (cfg.d_model,)),
            (f"layers.{l}.ln2", (cfg.d_model,)),
        ]
    out += [("emb", (VOCAB, cfg.d_model)), ("ln_f", (cfg.d_model,))]
    return out


def init_params(cfg: ModelConfig, seed: int = 0) -> dict[str, jnp.ndarray]:
    key = jax.random.PRNGKey(seed)
    params = {}
    for name, shape in tensor_manifest(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("ln1", "ln2", "ln_f")):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name == "emb":
            params[name] = jax.random.normal(sub, shape, jnp.float32) * 0.02
        else:
            fan_in = shape[0]
            params[name] = jax.random.normal(sub, shape, jnp.float32) \
                * (1.0 / np.sqrt(fan_in))
    return params


def params_to_flat(params: dict, cfg: ModelConfig) -> np.ndarray:
    return np.concatenate(
        [np.asarray(params[n], np.float32).reshape(-1)
         for n, _ in tensor_manifest(cfg)]
    )


def flat_to_params(flat: np.ndarray, cfg: ModelConfig) -> dict:
    params, off = {}, 0
    for name, shape in tensor_manifest(cfg):
        size = int(np.prod(shape))
        params[name] = jnp.asarray(flat[off:off + size].reshape(shape))
        off += size
    assert off == flat.size, f"weight blob size mismatch: {off} != {flat.size}"
    return params


def make_omega(cfg: ModelConfig, n_feat: int, seed: int = 42) -> np.ndarray:
    """The shared random projection Omega [n, d_head] (Eq. 4).

    Rows are *orthogonal* random features (Choromanski et al. §3:
    block-orthogonal gaussian with chi-distributed row norms) — same
    expectation as iid gaussian rows but strictly lower estimator
    variance, which directly tightens Theorem 2's effective gap.
    """
    rng = np.random.RandomState(seed)
    d = cfg.d_head
    blocks = []
    remaining = n_feat
    while remaining > 0:
        g = rng.randn(d, d)
        q, _ = np.linalg.qr(g)
        # Restore gaussian row norms (chi_d distributed).
        norms = np.linalg.norm(rng.randn(d, d), axis=1)
        blocks.append((q * norms[:, None])[: min(remaining, d)])
        remaining -= d
    return np.concatenate(blocks).astype(np.float32)


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float) -> jnp.ndarray:
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope(x: jnp.ndarray, pos: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding, half-split (llama) convention.

    x: [..., d_head]; pos: broadcastable int positions [...]."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = pos[..., None].astype(jnp.float32) * freqs     # [..., half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def mlp(x, w1, w2):
    return jax.nn.gelu(x @ w1) @ w2


# ---------------------------------------------------------------------------
# Full-sequence forward (training / oracle) — pure jnp
# ---------------------------------------------------------------------------

def forward(params: dict, cfg: ModelConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    """tokens: [B, T] int32 -> logits [B, T, V]. Full causal attention."""
    B, T = tokens.shape
    H, dh = cfg.n_heads, cfg.d_head
    x = params["emb"][tokens]                            # [B, T, d]
    pos = jnp.arange(T)
    causal = jnp.where(
        jnp.tril(jnp.ones((T, T), bool)), 0.0, -jnp.inf
    )
    for l in range(cfg.n_layers):
        p = {k.split(".", 2)[2]: v for k, v in params.items()
             if k.startswith(f"layers.{l}.")}
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        q = (h @ p["wq"]).reshape(B, T, H, dh)
        k = (h @ p["wk"]).reshape(B, T, H, dh)
        v = (h @ p["wv"]).reshape(B, T, H, dh)
        q = rope(q, pos[None, :, None], cfg.rope_theta)
        k = rope(k, pos[None, :, None], cfg.rope_theta)
        scores = jnp.einsum("bthd,bshd->bhts", q, k) / np.sqrt(dh)
        probs = jax.nn.softmax(scores + causal[None, None], axis=-1)
        attn = jnp.einsum("bhts,bshd->bthd", probs, v).reshape(B, T, H * dh)
        x = x + attn @ p["wo"]
        x = x + mlp(rmsnorm(x, p["ln2"], cfg.norm_eps), p["w1"], p["w2"])
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return x @ params["emb"].T


# ---------------------------------------------------------------------------
# Decode step (the serving hot path; AOT-lowered per bucket)
# ---------------------------------------------------------------------------

def decode_step_fn(cfg: ModelConfig, B: int, S: int, use_pallas: bool = True):
    """Returns fn(*weights, omega, tokens, pos, K, V, mask) -> tuple.

    Shapes (the L2<->L3 ABI; see DESIGN.md §8):
      tokens [B] i32, pos [B] i32,
      K, V   [B, L, H, S, dh] f32   gathered cache (policy-selected),
      mask   [B, L, H, S] f32       additive (0 keep / -1e30 pad) —
                                    per-(layer, head): selections may
                                    dedup differently per head,
    ->
      logits   [B, V],
      k_new    [B, L, H, dh]   post-RoPE key of this token,
      v_new    [B, L, H, dh],
      feat_new [B, L, H, n]    phi_Omega(k_new)  (Eq. 4),
      probs    [B, L, H, S+1]  attention over gathered+self (for H2O).
    """
    L, H, dh = cfg.n_layers, cfg.n_heads, cfg.d_head
    names = [n for n, _ in tensor_manifest(cfg)]
    attend = attend_decode_pallas if use_pallas else (
        lambda q, k, v, ks, vs, m: kref.attend_decode_ref(q, k, v, ks, vs, m)
    )
    phi = phi_pallas if use_pallas else kref.phi_ref

    def fn(*args):
        params = dict(zip(names, args[: len(names)]))
        omega, tokens, pos, K, V, mask = args[len(names):]
        x = params["emb"][tokens]                        # [B, d]
        k_news, v_news, feat_news, probs_all = [], [], [], []
        for l in range(L):
            p = {k.split(".", 2)[2]: v for k, v in params.items()
                 if k.startswith(f"layers.{l}.")}
            h = rmsnorm(x, p["ln1"], cfg.norm_eps)
            q = (h @ p["wq"]).reshape(B, H, dh)
            k = (h @ p["wk"]).reshape(B, H, dh)
            v = (h @ p["wv"]).reshape(B, H, dh)
            q = rope(q, pos[:, None], cfg.rope_theta)
            k = rope(k, pos[:, None], cfg.rope_theta)
            # Flatten (B, H) -> G for the kernel.
            G = B * H
            out, probs = attend(
                q.reshape(G, dh),
                K[:, l].reshape(G, S, dh),
                V[:, l].reshape(G, S, dh),
                k.reshape(G, dh),
                v.reshape(G, dh),
                mask[:, l].reshape(G, S),
            )
            attn = out.reshape(B, H * dh)
            x = x + attn @ p["wo"]
            x = x + mlp(rmsnorm(x, p["ln2"], cfg.norm_eps), p["w1"], p["w2"])
            k_news.append(k)
            v_news.append(v)
            feat_news.append(phi(k.reshape(G, dh), omega).reshape(B, H, -1))
            probs_all.append(probs.reshape(B, H, S + 1))
        xf = rmsnorm(x, params["ln_f"], cfg.norm_eps)
        logits = xf @ params["emb"].T
        return (
            logits,
            jnp.stack(k_news, axis=1),     # [B, L, H, dh]
            jnp.stack(v_news, axis=1),
            jnp.stack(feat_news, axis=1),  # [B, L, H, n]
            jnp.stack(probs_all, axis=1),  # [B, L, H, S+1]
        )

    return fn


# ---------------------------------------------------------------------------
# Chunked prefill (AOT-lowered per past-length bucket)
# ---------------------------------------------------------------------------

def prefill_fn(cfg: ModelConfig, T: int, P: int, use_pallas: bool = True):
    """Returns fn(*weights, omega, tokens, pos0, pastK, pastV, past_mask).

    Shapes:
      tokens [T] i32, pos0 [] i32 (chunk start position),
      pastK/pastV [L, H, P, dh], past_mask [P] additive,
    ->
      logits   [T, V],
      k_chunk  [L, H, T, dh], v_chunk [L, H, T, dh],
      feat_c   [L, H, T, n],
      colsum   [L, H, P+T]  per-key attention mass (H2O / SnapKV signal).
    """
    L, H, dh = cfg.n_layers, cfg.n_heads, cfg.d_head
    names = [n for n, _ in tensor_manifest(cfg)]
    attend = attend_prefill_pallas if use_pallas else kref.attend_prefill_ref
    phi = phi_pallas if use_pallas else kref.phi_ref

    def fn(*args):
        params = dict(zip(names, args[: len(names)]))
        omega, tokens, pos0, pastK, pastV, past_mask = args[len(names):]
        x = params["emb"][tokens]                        # [T, d]
        pos = pos0 + jnp.arange(T)
        k_cs, v_cs, feat_cs, colsums = [], [], [], []
        for l in range(L):
            p = {k.split(".", 2)[2]: v for k, v in params.items()
                 if k.startswith(f"layers.{l}.")}
            h = rmsnorm(x, p["ln1"], cfg.norm_eps)
            q = (h @ p["wq"]).reshape(T, H, dh).transpose(1, 0, 2)  # [H,T,dh]
            k = (h @ p["wk"]).reshape(T, H, dh).transpose(1, 0, 2)
            v = (h @ p["wv"]).reshape(T, H, dh).transpose(1, 0, 2)
            q = rope(q, pos[None, :], cfg.rope_theta)
            k = rope(k, pos[None, :], cfg.rope_theta)
            out, colsum = attend(
                q, pastK[l], pastV[l], k, v,
                jnp.broadcast_to(past_mask[None], (H, P)),
            )                                            # [H,T,dh], [H,P+T]
            attn = out.transpose(1, 0, 2).reshape(T, H * dh)
            x = x + attn @ p["wo"]
            x = x + mlp(rmsnorm(x, p["ln2"], cfg.norm_eps), p["w1"], p["w2"])
            k_cs.append(k)
            v_cs.append(v)
            feat_cs.append(phi(k.reshape(H * T, dh), omega).reshape(H, T, -1))
            colsums.append(colsum)
        xf = rmsnorm(x, params["ln_f"], cfg.norm_eps)
        logits = xf @ params["emb"].T
        return (
            logits,
            jnp.stack(k_cs),      # [L, H, T, dh]
            jnp.stack(v_cs),
            jnp.stack(feat_cs),   # [L, H, T, n]
            jnp.stack(colsums),   # [L, H, P+T]
        )

    return fn


# ---------------------------------------------------------------------------
# Per-layer decode pipeline (the Radar path).
#
# Radar's segment search needs phi(q) at layer l BEFORE the layer-l KV
# gather, so the fused one-dispatch decode graph cannot serve it. These
# two generic layer artifacts (weights are inputs, so one compiled
# program serves every layer) let rust interleave: qkv -> select ->
# gather -> attn_mlp, per layer — Algorithm 1's structure.
# ---------------------------------------------------------------------------

def qkv_fn(cfg: ModelConfig, B: int, use_pallas: bool = True):
    """fn(wq, wk, wv, ln1, omega, x [B,d], pos [B]) ->
    (q, k, v [B,H,dh] post-RoPE, phi_q, phi_k [B,H,n])."""
    H, dh = cfg.n_heads, cfg.d_head
    phi = phi_pallas if use_pallas else kref.phi_ref

    def fn(wq, wk, wv, ln1, omega, x, pos):
        h = rmsnorm(x, ln1, cfg.norm_eps)
        q = rope((h @ wq).reshape(B, H, dh), pos[:, None], cfg.rope_theta)
        k = rope((h @ wk).reshape(B, H, dh), pos[:, None], cfg.rope_theta)
        v = (h @ wv).reshape(B, H, dh)
        G = B * H
        phi_q = phi(q.reshape(G, dh), omega).reshape(B, H, -1)
        phi_k = phi(k.reshape(G, dh), omega).reshape(B, H, -1)
        return q, k, v, phi_q, phi_k

    return fn


def attn_mlp_fn(cfg: ModelConfig, B: int, S: int, use_pallas: bool = True):
    """fn(wo, w1, w2, ln2, x [B,d], q,k,v [B,H,dh],
          K,V [B,H,S,dh], mask [B,H,S]) -> (x_out [B,d], probs [B,H,S+1]).

    Attention over the gathered set + self, residual, MLP block."""
    H, dh = cfg.n_heads, cfg.d_head
    attend = attend_decode_pallas if use_pallas else kref.attend_decode_ref

    def fn(wo, w1, w2, ln2, x, q, k, v, K, V, mask):
        G = B * H
        out, probs = attend(
            q.reshape(G, dh), K.reshape(G, S, dh), V.reshape(G, S, dh),
            k.reshape(G, dh), v.reshape(G, dh),
            mask.reshape(G, S),
        )
        x = x + out.reshape(B, H * dh) @ wo
        x = x + mlp(rmsnorm(x, ln2, cfg.norm_eps), w1, w2)
        return x, probs.reshape(B, H, S + 1)

    return fn


def config_dict(cfg: ModelConfig) -> dict:
    d = asdict(cfg)
    d["vocab"] = VOCAB
    return d
