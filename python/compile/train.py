"""Build-time LM training (hand-rolled Adam — no optax offline).

Trains the byte-level models on the synthetic mixture corpus
(``data.training_corpus``), whose planted recall spans force genuinely
long-range attention heads — the substrate the serving experiments need
(DESIGN.md §4). Checkpoints overwrite ``artifacts/<model>/weights.npz``;
run ``aot.py --golden-only`` afterwards to refresh the golden vectors.

Python-only, build-time-only: never on the serving path.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M
from compile.data import training_corpus


def cross_entropy(params, cfg, tokens):
    """tokens: [B, T+1] -> mean next-byte CE over the window."""
    logits = M.forward(params, cfg, tokens[:, :-1])
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


def adam_init(params):
    zeros = lambda: {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": zeros(), "v": zeros(), "t": jnp.int32(0)}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.99, eps=1e-8):
    t = state["t"] + 1
    new_m, new_v, new_p = {}, {}, {}
    bc1 = 1.0 - b1 ** t.astype(jnp.float32)
    bc2 = 1.0 - b2 ** t.astype(jnp.float32)
    for k in params:
        m = b1 * state["m"][k] + (1 - b1) * grads[k]
        v = b2 * state["v"][k] + (1 - b2) * grads[k] ** 2
        new_m[k], new_v[k] = m, v
        new_p[k] = params[k] - lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
    return new_p, {"m": new_m, "v": new_v, "t": t}


def make_batch(corpus: np.ndarray, rng: np.random.RandomState,
               batch: int, seq: int) -> np.ndarray:
    starts = rng.randint(0, len(corpus) - seq - 1, size=batch)
    return np.stack([corpus[s : s + seq + 1] for s in starts]).astype(np.int32)


def train(model_name: str, steps: int, out_root: str, seq: int, batch: int,
          lr_max: float, seed: int = 0, resume: bool = False) -> None:
    cfg = M.CONFIGS[model_name]
    out = os.path.join(out_root, model_name)
    os.makedirs(out, exist_ok=True)
    corpus = np.frombuffer(training_corpus(2_000_000, seed=3), np.uint8)
    wpath = os.path.join(out, "weights.npz")
    if resume and os.path.exists(wpath):
        loaded = np.load(wpath)
        params = {k: jnp.asarray(loaded[k]) for k in loaded.files}
        print(f"[{model_name}] resumed from {wpath}")
    else:
        params = M.init_params(cfg, seed=seed)
    state = adam_init(params)
    warmup = max(steps // 20, 5)

    @jax.jit
    def step_fn(params, state, tokens, lr):
        loss, grads = jax.value_and_grad(cross_entropy)(params, cfg, tokens)
        params, state = adam_update(params, grads, state, lr)
        return params, state, loss

    rng = np.random.RandomState(seed + 1)
    log, t0 = [], time.time()
    for step in range(steps):
        if step < warmup:
            lr = lr_max * (step + 1) / warmup
        else:
            frac = (step - warmup) / max(steps - warmup, 1)
            lr = lr_max * 0.5 * (1 + np.cos(np.pi * frac))
        tokens = jnp.asarray(make_batch(corpus, rng, batch, seq))
        params, state, loss = step_fn(params, state, tokens, jnp.float32(lr))
        if step % 10 == 0 or step == steps - 1:
            l = float(loss)
            log.append({"step": step, "loss": l, "lr": float(lr),
                        "sec": round(time.time() - t0, 1)})
            print(f"[{model_name}] step {step:4d} loss {l:.4f} "
                  f"lr {lr:.2e} ({time.time()-t0:.0f}s)", flush=True)
    np.savez(os.path.join(out, "weights.npz"),
             **{k: np.asarray(v) for k, v in params.items()})
    with open(os.path.join(out, "train_log.json"), "w") as f:
        json.dump(log, f, indent=1)
    print(f"[{model_name}] saved weights ({time.time()-t0:.0f}s total)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--model", default="sm")
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--resume", action="store_true",
                    help="continue from the existing checkpoint")
    args = ap.parse_args()
    train(args.model, args.steps, args.out, args.seq, args.batch, args.lr,
          resume=args.resume)


if __name__ == "__main__":
    main()
