"""AOT compile path: lower every serving graph to HLO text artifacts.

This is the only place python touches the serving stack; it runs at
``make artifacts`` and never again. Outputs, per model:

  artifacts/<model>/manifest.json      model config + artifact registry
                                       + weight tensor ABI
  artifacts/<model>/weights.npz        trained (or seeded-random) weights
  artifacts/<model>/omega_n{N}.npz     random projection Omega (Eq. 4)
  artifacts/<model>/decode_b{B}_s{S}_n{N}.hlo.txt
  artifacts/<model>/prefill_t{T}_p{P}_n{N}.hlo.txt
  artifacts/<model>/golden.npz         replay vectors for rust integration
                                       tests (inputs + expected outputs)

HLO **text** is the interchange format: the image's xla_extension 0.5.1
rejects jax>=0.5 serialized protos (64-bit instruction ids); the text
parser reassigns ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as M

# Bucket tables (DESIGN.md §7). Decode S and prefill P are multiples of
# the kernels' BLOCK_S=128 so the streaming grids tile exactly.
DECODE_BUCKETS = {
    "sm": [(1, 128), (1, 256), (1, 512), (1, 1024), (1, 2048), (1, 4096),
           (2, 128), (2, 256), (2, 512), (2, 1024),
           (4, 128), (4, 256), (4, 512), (4, 1024)],
    "md": [(1, 128), (1, 256), (1, 512), (1, 1024), (1, 2048), (2, 256)],
}
NSWEEP = {"sm": [32, 64, 256, 512], "md": []}   # extra n variants at (B=1, S=256)
QKV_BATCH = {"sm": [1, 2, 4], "md": [1, 2]}
PREFILL_T = 128
PREFILL_BUCKETS = {
    "sm": [0, 256, 512, 1024, 2048, 4096],
    "md": [0, 256, 512, 1024, 2048],
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _f32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _i32(shape=()):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def weight_specs(cfg: M.ModelConfig):
    return [_f32(shape) for _, shape in M.tensor_manifest(cfg)]


def lower_decode(cfg: M.ModelConfig, B: int, S: int, n_feat: int) -> str:
    fn = M.decode_step_fn(cfg, B, S, use_pallas=True)
    L, H, dh = cfg.n_layers, cfg.n_heads, cfg.d_head
    specs = weight_specs(cfg) + [
        _f32((n_feat, dh)),            # omega
        _i32((B,)), _i32((B,)),        # tokens, pos
        _f32((B, L, H, S, dh)),        # K
        _f32((B, L, H, S, dh)),        # V
        _f32((B, L, H, S)),            # mask (per layer+head)
    ]
    return to_hlo_text(jax.jit(fn).lower(*specs))


def lower_qkv(cfg: M.ModelConfig, B: int, n_feat: int) -> str:
    fn = M.qkv_fn(cfg, B, use_pallas=True)
    d, a, dh = cfg.d_model, cfg.d_attn, cfg.d_head
    specs = [
        _f32((d, a)), _f32((d, a)), _f32((d, a)), _f32((d,)),  # wq wk wv ln1
        _f32((n_feat, dh)),                                    # omega
        _f32((B, d)), _i32((B,)),                              # x, pos
    ]
    return to_hlo_text(jax.jit(fn).lower(*specs))


def lower_attn_mlp(cfg: M.ModelConfig, B: int, S: int) -> str:
    fn = M.attn_mlp_fn(cfg, B, S, use_pallas=True)
    d, a, dh, H, f = cfg.d_model, cfg.d_attn, cfg.d_head, cfg.n_heads, cfg.d_ffn
    specs = [
        _f32((a, d)), _f32((d, f)), _f32((f, d)), _f32((d,)),  # wo w1 w2 ln2
        _f32((B, d)),                                          # x
        _f32((B, H, dh)), _f32((B, H, dh)), _f32((B, H, dh)),  # q k v
        _f32((B, H, S, dh)), _f32((B, H, S, dh)),              # K V
        _f32((B, H, S)),                                       # mask (per head)
    ]
    return to_hlo_text(jax.jit(fn).lower(*specs))


def lower_prefill(cfg: M.ModelConfig, T: int, P: int, n_feat: int) -> str:
    fn = M.prefill_fn(cfg, T, P, use_pallas=True)
    L, H, dh = cfg.n_layers, cfg.n_heads, cfg.d_head
    specs = weight_specs(cfg) + [
        _f32((n_feat, dh)),            # omega
        _i32((T,)), _i32(()),          # tokens, pos0
        _f32((L, H, P, dh)),           # pastK
        _f32((L, H, P, dh)),           # pastV
        _f32((P,)),                    # past_mask
    ]
    return to_hlo_text(jax.jit(fn).lower(*specs))


# ---------------------------------------------------------------------------
# Golden replay vectors
# ---------------------------------------------------------------------------

def make_golden(cfg: M.ModelConfig, params: dict, omega: np.ndarray) -> dict:
    """Concrete inputs + expected outputs for the smallest decode and
    prefill buckets; the rust integration test executes the compiled
    artifacts on these inputs and asserts allclose(1e-4)."""
    L, H, dh, n = cfg.n_layers, cfg.n_heads, cfg.d_head, cfg.n_feat
    B, S, T, P = 1, 128, PREFILL_T, 256
    rng = np.random.RandomState(1234)
    g = {}
    # --- decode ---
    g["dec_tokens"] = rng.randint(0, 255, (B,)).astype(np.int32)
    g["dec_pos"] = np.array([40], np.int32)
    g["dec_K"] = rng.randn(B, L, H, S, dh).astype(np.float32) * 0.3
    g["dec_V"] = rng.randn(B, L, H, S, dh).astype(np.float32) * 0.3
    mask = np.zeros((B, L, H, S), np.float32)
    mask[..., 40:] = -1e30                     # 40 real tokens, rest padded
    g["dec_mask"] = mask
    weights = [np.asarray(params[nm]) for nm, _ in M.tensor_manifest(cfg)]
    fn = M.decode_step_fn(cfg, B, S, use_pallas=True)
    outs = fn(*weights, jnp.asarray(omega), g["dec_tokens"], g["dec_pos"],
              g["dec_K"], g["dec_V"], g["dec_mask"])
    for nm, o in zip(["logits", "k_new", "v_new", "feat_new", "probs"], outs):
        g[f"dec_out_{nm}"] = np.asarray(o)
    # --- prefill ---
    g["pre_tokens"] = rng.randint(0, 255, (T,)).astype(np.int32)
    g["pre_pos0"] = np.array(64, np.int32)
    g["pre_K"] = rng.randn(L, H, P, dh).astype(np.float32) * 0.3
    g["pre_V"] = rng.randn(L, H, P, dh).astype(np.float32) * 0.3
    pmask = np.zeros((P,), np.float32)
    pmask[64:] = -1e30                         # 64 real past tokens
    g["pre_mask"] = pmask
    pfn = M.prefill_fn(cfg, T, P, use_pallas=True)
    pouts = pfn(*weights, jnp.asarray(omega), g["pre_tokens"], g["pre_pos0"],
                g["pre_K"], g["pre_V"], g["pre_mask"])
    for nm, o in zip(["logits", "k_c", "v_c", "feat_c", "colsum"], pouts):
        g[f"pre_out_{nm}"] = np.asarray(o)
    # --- per-layer pipeline (layer 0 weights), B=1, S=128 ---
    d = cfg.d_model
    g["lay_x"] = rng.randn(1, d).astype(np.float32) * 0.5
    g["lay_pos"] = np.array([17], np.int32)
    p0 = {k.split(".", 2)[2]: params[k] for k in params
          if k.startswith("layers.0.")}
    qfn = M.qkv_fn(cfg, 1, use_pallas=True)
    qouts = qfn(p0["wq"], p0["wk"], p0["wv"], p0["ln1"], jnp.asarray(omega),
                g["lay_x"], g["lay_pos"])
    for nm, o in zip(["q", "k", "v", "phi_q", "phi_k"], qouts):
        g[f"lay_out_{nm}"] = np.asarray(o)
    g["lay_K"] = g["dec_K"][0, 0][None]                    # [1,H,S,dh]
    g["lay_V"] = g["dec_V"][0, 0][None]
    afn = M.attn_mlp_fn(cfg, 1, S, use_pallas=True)
    aouts = afn(p0["wo"], p0["w1"], p0["w2"], p0["ln2"],
                g["lay_x"], qouts[0], qouts[1], qouts[2],
                g["lay_K"], g["lay_V"], g["dec_mask"][:, 0])  # [1,H,S]
    g["lay_out_x"] = np.asarray(aouts[0])
    g["lay_out_probs"] = np.asarray(aouts[1])
    # --- embed + head (implemented rust-side; verified against these) ---
    g["head_x"] = rng.randn(2, d).astype(np.float32) * 0.5
    xe = params["emb"][jnp.asarray([5, 250])]
    g["emb_out"] = np.asarray(xe)
    xf = M.rmsnorm(jnp.asarray(g["head_x"]), params["ln_f"], cfg.norm_eps)
    g["head_out_logits"] = np.asarray(xf @ params["emb"].T)
    return g


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def build_model(model_name: str, out_root: str, skip_hlo: bool = False,
                golden_only: bool = False) -> None:
    cfg = M.CONFIGS[model_name]
    out = os.path.join(out_root, model_name)
    os.makedirs(out, exist_ok=True)

    # Weights: prefer a trained checkpoint; else deterministic random init
    # (training then overwrites + re-goldens).
    wpath = os.path.join(out, "weights.npz")
    if os.path.exists(wpath):
        loaded = np.load(wpath)
        params = {k: jnp.asarray(loaded[k]) for k in loaded.files}
        print(f"[{model_name}] loaded weights from {wpath}")
    else:
        params = M.init_params(cfg, seed=0)
        np.savez(wpath, **{k: np.asarray(v) for k, v in params.items()})
        print(f"[{model_name}] wrote seeded-random weights to {wpath}")

    n_feats = sorted({cfg.n_feat, *NSWEEP[model_name]})
    omegas = {}
    for n in n_feats:
        omegas[n] = M.make_omega(cfg, n, seed=42)
        np.savez(os.path.join(out, f"omega_n{n}.npz"), omega=omegas[n])

    golden = make_golden(cfg, params, omegas[cfg.n_feat])
    np.savez(os.path.join(out, "golden.npz"), **golden)
    print(f"[{model_name}] wrote golden replay vectors")
    if golden_only:
        return

    artifacts = []
    if not skip_hlo:
        for (B, S) in DECODE_BUCKETS[model_name]:
            name = f"decode_b{B}_s{S}_n{cfg.n_feat}"
            t0 = time.time()
            text = lower_decode(cfg, B, S, cfg.n_feat)
            open(os.path.join(out, name + ".hlo.txt"), "w").write(text)
            artifacts.append({"name": name, "kind": "decode", "B": B, "S": S,
                              "n": cfg.n_feat})
            print(f"[{model_name}] {name}: {len(text)//1024} KiB "
                  f"({time.time()-t0:.1f}s)")
        for n in NSWEEP[model_name]:
            B, S = 1, 256
            name = f"decode_b{B}_s{S}_n{n}"
            text = lower_decode(cfg, B, S, n)
            open(os.path.join(out, name + ".hlo.txt"), "w").write(text)
            artifacts.append({"name": name, "kind": "decode", "B": B, "S": S,
                              "n": n})
            print(f"[{model_name}] {name} done")
        for B in QKV_BATCH[model_name]:
            for n in sorted({cfg.n_feat, *NSWEEP[model_name]}):
                name = f"qkv_b{B}_n{n}"
                text = lower_qkv(cfg, B, n)
                open(os.path.join(out, name + ".hlo.txt"), "w").write(text)
                artifacts.append({"name": name, "kind": "qkv", "B": B, "n": n})
            for (BB, S) in DECODE_BUCKETS[model_name]:
                if BB != B:
                    continue
                name = f"attnmlp_b{B}_s{S}"
                text = lower_attn_mlp(cfg, B, S)
                open(os.path.join(out, name + ".hlo.txt"), "w").write(text)
                artifacts.append({"name": name, "kind": "attn_mlp",
                                  "B": B, "S": S, "n": cfg.n_feat})
            print(f"[{model_name}] per-layer artifacts for B={B} done")
        for n in NSWEEP[model_name]:
            # The n-sweep (Fig. 4) also needs prefill at matching n
            # (cache features are n-dimensional); short buckets suffice.
            for P in [0, 256]:
                name = f"prefill_t{PREFILL_T}_p{P}_n{n}"
                text = lower_prefill(cfg, PREFILL_T, P, n)
                open(os.path.join(out, name + ".hlo.txt"), "w").write(text)
                artifacts.append({"name": name, "kind": "prefill",
                                  "T": PREFILL_T, "P": P, "n": n})
        for P in PREFILL_BUCKETS[model_name]:
            name = f"prefill_t{PREFILL_T}_p{P}_n{cfg.n_feat}"
            t0 = time.time()
            text = lower_prefill(cfg, PREFILL_T, P, cfg.n_feat)
            open(os.path.join(out, name + ".hlo.txt"), "w").write(text)
            artifacts.append({"name": name, "kind": "prefill",
                              "T": PREFILL_T, "P": P, "n": cfg.n_feat})
            print(f"[{model_name}] {name}: {len(text)//1024} KiB "
                  f"({time.time()-t0:.1f}s)")

    manifest = {
        "config": M.config_dict(cfg),
        "tensors": [{"name": nm, "shape": list(sh)}
                    for nm, sh in M.tensor_manifest(cfg)],
        "artifacts": artifacts,
        "prefill_chunk": PREFILL_T,
    }
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[{model_name}] manifest written ({len(artifacts)} artifacts)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="sm,md")
    ap.add_argument("--golden-only", action="store_true",
                    help="refresh weights+golden without re-lowering HLO")
    args = ap.parse_args()
    for m in args.models.split(","):
        build_model(m, args.out, golden_only=args.golden_only)


if __name__ == "__main__":
    main()
